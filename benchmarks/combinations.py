"""Table 1 analogue: the swept parameter space + the paper's combination
count formula vs the exact enumeration, and sweep-cost scaling (the
"resources ComPar requires" discussion in §5/6)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import csv_row
from repro.configs import get_arch, get_shape
from repro.core import ComParTuner
from repro.core.combinator import (DEFAULT_CLAUSE_SPACE,
                                   DEFAULT_GLOBAL_SPACE, clause_grid,
                                   enumerate_combinations,
                                   paper_combination_count)
from repro.core.providers import all_providers


def run(fast: bool = False) -> List[str]:
    rows = []
    provs = all_providers()
    for name, p in sorted(provs.items()):
        rows.append(csv_row(f"combinations/provider/{name}", 0.0,
                            f"flags={len(p.flags)}:"
                            + "+".join(sorted(p.flags))))
    n_clauses = len(clause_grid(DEFAULT_CLAUSE_SPACE))
    rows.append(csv_row("combinations/clause_grid", 0.0,
                        f"size={n_clauses}"))
    exact = len(enumerate_combinations(sorted(provs)))
    formula = paper_combination_count(
        [len(p.flags) for p in provs.values()],
        n_rtl=len(DEFAULT_GLOBAL_SPACE), n_d=len(DEFAULT_CLAUSE_SPACE))
    rows.append(csv_row("combinations/exact_enumeration", 0.0,
                        f"count={exact}"))
    rows.append(csv_row("combinations/paper_formula_upper_bound", 0.0,
                        f"count={formula}"))

    # sweep-cost scaling: combinations vs wall time (dry-run executor)
    cfg = get_arch("stablelm-3b").smoke()
    shape = get_shape("train_4k").smoke()
    budgets = (2, 4) if fast else (2, 4, 8)
    for budget in budgets:
        t0 = time.time()
        tuner = ComParTuner(cfg, shape, mesh=None, executor="dryrun",
                            project=f"scaling-{budget}", timeout_s=120)
        space = {"remat": ("none", "dots", "full"),
                 "kernel": ("xla",), "block_q": (16, 32),
                 "block_k": (16,), "scan_unroll": (1,),
                 "mlstm_chunk": (16,)}
        plan, rep = tuner.sweep(providers=["tensor_par", "fsdp"],
                                clause_space=space, budget=budget,
                                max_flags=0)
        dt = time.time() - t0
        rows.append(csv_row(f"combinations/sweep_cost/budget{budget}",
                            dt * 1e6 / max(rep.n_done, 1),
                            f"combos={rep.n_combinations};"
                            f"elapsed_s={dt:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
