"""Shared benchmark helpers: wall-clock timing of jitted programs."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np


def time_jitted(fn: Callable, args, *, repeats: int = 3,
                warmup: int = 1) -> float:
    """Median wall-clock seconds of fn(*args) (pre-compiled via first call)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
