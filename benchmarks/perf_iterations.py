import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""EXPERIMENTS §Perf driver: hypothesis -> change -> measure -> validate.

Three hillclimb cells (chosen from the baseline roofline table):
  A stablelm-3b x train_4k   — worst roofline fraction (dense train)
  B qwen3-moe   x train_4k   — most collective-bound (paper-representative:
                               the tuner's provider/dispatch choice)
  C granite-8b  x decode_32k — serving path, memory-bound KV reads

Each iteration is a (plan-delta, hypothesis) pair; the driver lowers the
cell on the single-pod mesh, records the three roofline terms, and prints
before/after vs the previous accepted iteration.  Results accumulate in
perf_results.json (Continue-mode like the dry-run).

    PYTHONPATH=src python -m benchmarks.perf_iterations [--cell A|B|C]
"""
import argparse
import dataclasses
import json

from repro.configs import get_arch, get_shape
from repro.core.combinator import GlobalKnobs
from repro.core.plan import uniform_plan
from repro.launch.dryrun import default_plan, run_cell
from repro.models.context import SegmentClause

OUT = os.path.join(os.path.dirname(__file__), "..", "perf_results.json")


def plan_variant(cfg, shape, *, provider=None, flags=None, clause_kw=None,
                 knob_kw=None):
    base = default_plan(cfg, shape)
    combo = next(iter(base.segments.values()))
    provider = provider or combo.provider
    flags = frozenset(flags) if flags is not None else combo.flags
    clause = dataclasses.replace(combo.clause, **(clause_kw or {}))
    knobs = dataclasses.replace(base.knobs, **(knob_kw or {}))
    return uniform_plan(cfg, provider, flags, clause, knobs)


ITERATIONS = {
    "A": [
        ("A0-baseline", "paper-faithful default: hybrid2d TP16, remat=dots,"
         " mb=1. Expect collective-heavy (2 ARs/layer of the bf16 residual"
         " x fwd+bwd+remat) and >16GiB/dev peak.", {}),
        ("A1-fsdp", "switch provider to fsdp[shard_both_axes]: per-layer"
         " param all-gathers (~170MB/layer) replace residual ARs"
         " (~4x335MB/layer). Napkin: collective 5.7s -> ~0.6s.",
         dict(provider="fsdp", flags={"shard_both_axes"})),
        ("A2-mb4", "A1 + microbatches=4: 4x smaller live activations ->"
         " peak bytes/dev ~/4 (fits 16GiB); terms ~unchanged (same total"
         " work).", dict(provider="fsdp", flags={"shard_both_axes"},
                         knob_kw=dict(microbatches=4))),
        ("A3-noremat", "A2 + remat=none: drop recompute; compute term"
         " -~25% (no fwd replay) at the cost of saved activations;"
         " mb=4 keeps the peak bounded.",
         dict(provider="fsdp", flags={"shard_both_axes"},
              clause_kw=dict(remat="none"), knob_kw=dict(microbatches=4))),
        ("A4-fsdp-dpom", "A1 was REFUTED because pure FSDP idles the"
         " model axis (batch only 16-way -> 16x per-chip FLOPs). Add"
         " dp_over_model: batch 256-way, params 256-way. Napkin: compute"
         " back to 0.44s, collective = per-layer param AG ~0.4s.",
         dict(provider="fsdp",
              flags={"shard_both_axes", "dp_over_model"},
              knob_kw=dict(microbatches=1))),
        ("A5-seqpar", "alternative: hybrid2d + Megatron sequence"
         " parallelism (residual stream sharded over model between"
         " blocks): AR -> RS+AG pairs, sharded saved activations."
         " Napkin: collective ~same bytes, peak /~4.",
         dict(provider="hybrid2d", flags={"shard_vocab", "seq_parallel"},
              knob_kw=dict(microbatches=4))),
    ],
    "B": [
        ("B0-baseline", "paper-faithful default: expert_par"
         "[tp_attention,fsdp_dense,2d_experts], sorted-dispatch MoE."
         " SPMD partitioner gathers dispatch buffers across expert shards"
         " -> collective-dominant (~36s est).", {}),
        ("B1-a2a", "shard_map expert-parallel dispatch: tokens stay"
         " data-sharded + replicated over model; each shard runs only its"
         " E/16 experts; ONE psum(T_local,d)/layer. Napkin: collective"
         " ~36s -> <2s.", dict(clause_kw=dict(moe_dispatch="a2a"))),
        ("B2-a2a-mb4", "B1 + microbatches=4 for peak fit"
         " (142GiB/dev baseline): activations /4.",
         dict(clause_kw=dict(moe_dispatch="a2a"),
              knob_kw=dict(microbatches=4))),
        ("B3-bf16psum", "B2 + combine partials in bf16 before the psum"
         " (f32 partial sums halve to bf16): per-layer collective bytes"
         " /2 on the MoE combine.",
         dict(clause_kw=dict(moe_dispatch="a2a"),
              knob_kw=dict(microbatches=4))),
    ],
    "D": [
        ("D0-baseline", "hybrid2d default. starcoder2 has 24 heads and"
         " kv=2: NEITHER divides the 16-way model axis, so attention"
         " falls back to fully-replicated over model = 16x redundant"
         " attention compute+memory (MF/HLO ratio ~0.1).", {}),
        ("D1-fsdp-dpom", "providers that never shard heads dodge the"
         " divisibility wall: fsdp[shard_both_axes,dp_over_model]"
         " shards batch 256-way. Napkin: compute 3.15 -> ~0.4s,"
         " memory 40 -> ~4s. This is the paper's core claim in action:"
         " the best 'compiler' differs per architecture.",
         dict(provider="fsdp",
              flags={"shard_both_axes", "dp_over_model"})),
        ("D2-mb4", "D1 + microbatches=4 to bring peak under HBM.",
         dict(provider="fsdp",
              flags={"shard_both_axes", "dp_over_model"},
              knob_kw=dict(microbatches=4))),
    ],
    "C": [
        ("C0-baseline", "paper-faithful default: tensor_par decode,"
         " f32-upcast KV reads (naive). Memory-bound: cache read traffic"
         " ~3x the bf16 cache size.", {}),
        ("C1-bf16read", "read the KV cache in bf16 with f32 accumulation"
         " (preferred_element_type): same MXU math, 1/3 the bytes."
         " Napkin: memory 0.70s -> ~0.25s.",
         dict(clause_kw=dict(cache_upcast=False))),
        ("C2-fsdp-batch", "alternative sharding: fsdp provider shards"
         " batch only (cache not seq-sharded) — hypothesis: WORSE for"
         " kv=8 (cache replicated over model axis 16); refutation case"
         " demonstrating the baseline TP choice was right.",
         dict(provider="fsdp", flags=set())),
        ("C3-shardmap", "root cause of C0's 0.68s: SPMD handles the dus"
         " into the seq-sharded cache by INVOLUNTARY FULL"
         " REMATERIALIZATION (replicate+reshard per layer, ~36x cache"
         " traffic). shard_map decode: local dus when pos is in-range +"
         " one LSE psum combine. Napkin: memory -> ~0.01s.",
         dict(clause_kw=dict(decode_shardmap=True, cache_upcast=False))),
    ],
}

CELLS = {
    "A": ("stablelm-3b", "train_4k"),
    "B": ("qwen3-moe-30b-a3b", "train_4k"),
    "C": ("granite-8b", "decode_32k"),
    "D": ("starcoder2-3b", "train_4k"),
}


def run_iterations(cell: str, timeout_s: int = 1700):
    arch, shape_name = CELLS[cell]
    cfg, shape = get_arch(arch), get_shape(shape_name)
    results = {}
    if os.path.exists(OUT):
        with open(OUT) as f:
            results = json.load(f)
    prev = None
    for name, hypothesis, delta in ITERATIONS[cell]:
        key = f"{cell}/{name}"
        if key in results and results[key].get("status") == "ok":
            rec = results[key]
            print(f"[perf] {key}: cached")
        else:
            plan = plan_variant(cfg, shape, **delta) if delta else None
            rec = run_cell(arch, shape_name, multi_pod=False, plan=plan,
                           timeout_s=timeout_s, verbose=False)
            rec["hypothesis"] = hypothesis
            results[key] = rec
            with open(OUT, "w") as f:
                json.dump(results, f, indent=1)
        if rec["status"] != "ok":
            print(f"[perf] {key} FAILED: {rec.get('error')}")
            continue
        c = rec["cost"]
        line = (f"[perf] {key}: compute={c['compute_s']:.4f} "
                f"memory={c['memory_s']:.4f} "
                f"collective={c['collective_s']:.4f} "
                f"total={c['total_s']:.4f} dom={rec['dominant']} "
                f"peak={c['bytes_per_device']/2**30:.1f}GiB")
        if prev is not None and prev["status"] == "ok":
            p = prev["cost"]
            line += (f"  [total {p['total_s']:.4f} -> {c['total_s']:.4f}, "
                     f"{p['total_s']/max(c['total_s'],1e-12):.2f}x]")
        print(line)
        prev = rec
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=[None, "A", "B", "C", "D"])
    ap.add_argument("--timeout", type=int, default=1700)
    args = ap.parse_args()
    cells = [args.cell] if args.cell else ["A", "B", "C", "D"]
    for c in cells:
        print(f"=== hillclimb cell {c}: {CELLS[c]} ===")
        run_iterations(c, args.timeout)


if __name__ == "__main__":
    main()
