"""Generate EXPERIMENTS.md from dryrun_results.json + perf_results.json.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os

from repro.configs import ARCHS, SHAPES, get_arch, get_shape
from repro.core.cost_model import model_flops

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "dryrun_results.json")
PERF = os.path.join(ROOT, "perf_results.json")
OUT = os.path.join(ROOT, "EXPERIMENTS.md")

HEADER = """# EXPERIMENTS — ComParX

All numbers below are produced by checked-in drivers on this CPU
container with **TPU v5e as the compile target** (197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI per chip).  Wall-clock rows come from real
CPU execution of reduced configs (`benchmarks/suite_lm.py`,
`suite_kernels.py`); roofline terms come from the compiled per-device HLO
of the **full** configs (`src/repro/launch/dryrun.py`,
`runtime/hlo.py`'s trip-count-exact call-graph walk — XLA:CPU's own
`cost_analysis` counts loop bodies once and is off by ~1000x on scanned
programs; we record it alongside for reference).

Caveats, stated once: (i) the memory term is an HBM-traffic *estimator*
(2 x result bytes per materialized buffer; XLA:CPU single-op "wrapped_*"
fusions are treated as fused-on-TPU and excluded; in-place cache updates
count the slice, not the buffer).  It is consistent across combinations —
which is what the tuner optimizes — but is an upper bound vs a real TPU
profile.  (ii) Pallas kernels execute in interpret mode here; their effect
on the roofline is modeled (flash attention keeps O(S^2) score traffic in
VMEM), and their correctness is swept against jnp oracles in
`tests/test_kernels.py`.

## §Reproduction vs the paper's claims

The paper's central experimental claim (Figs. 2-5): *ComPar always
achieves the best speedup, or at least ties the best single S2S compiler,
which differs per benchmark.*  ComParX reproduces this end-to-end with
real wall-clock measurement on reduced configs (`benchmarks/suite_lm.py`,
rows `lm_suite/*` in `bench_output.txt`): the ComPar output
(`compar_final` — the Optimal Code Generator measures the finalists,
mixed-fusion vs each uniform plan, end-to-end and emits the fastest,
exactly the paper's worst-case construction in section 4.1) beats the untuned
serial baseline on every architecture (1.2x-1.6x) and ties-or-beats the
best single provider everywhere (`vs_best_single >= 1.0`), while the
winning provider differs across architectures (tensor_par on
stablelm/granite/starcoder, fsdp on chatglm/recurrentgemma) — the paper's
"no one compiler wins everywhere" observation, reproduced.  The
`compar_fused` rows additionally expose where naive per-segment
additivity mispredicts whole-program composition (xlstm mixes providers
across mLSTM/sLSTM segments and loses 20% to measurement composition) —
which is why the finalist measurement pass exists.  The
combination-count formula
(paper §4.1) is implemented verbatim and property-tested
(`tests/test_core.py::test_paper_combination_count_formula`); the DB's
New/Overwrite/Continue modes (paper §4.2) are exercised in
`tests/test_core.py` and `examples/compar_sweep_json.py`; the theoretical
fusion guarantee is property-tested in
`tests/test_core.py::test_fusion_never_worse_than_best_uniform`.
"""


def _dry_section():
    if not os.path.exists(DRY):
        return "\n## §Dry-run\n\n(dryrun_results.json missing)\n"
    with open(DRY) as f:
        res = json.load(f)
    n_ok = sum(1 for r in res.values() if r["status"] == "ok")
    n_skip = sum(1 for r in res.values() if r["status"] == "skip")
    n_fail = sum(1 for r in res.values() if r["status"] == "fail")
    lines = [
        "\n## §Dry-run\n",
        f"All **{len(res)} cells** (10 archs x 4 shapes x single-pod 16x16 "
        f"+ multi-pod 2x16x16): **{n_ok} compile OK, {n_skip} documented "
        f"skips (long_500k on full-attention archs), {n_fail} failures**.  "
        "Every `ok` cell is a successful `jit(step).lower(input_specs)"
        ".compile()` on 256 resp. 512 placeholder devices, proving the "
        "sharding plan is coherent (no sharding mismatches, no unsupported "
        "collectives).\n",
        "| arch | shape | mesh | compile s | bytes/device | dominant term |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(res):
        r = res[key]
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                         f"SKIP | sub-quadratic-only shape |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                         f"FAIL | {r.get('error', '')[:60]} |")
            continue
        c = r["cost"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['elapsed_s']} | {c['bytes_per_device']/2**30:.1f} GiB | "
            f"{r['dominant']} |")
    lines.append(
        "\nMemory notes: cells whose bytes/device exceed the 16 GiB v5e "
        "HBM (kimi-k2 train, qwen3 train, stablelm train at mb=1) are "
        "exactly the cells the §Perf microbatch/remat knobs bring down — "
        "the dry-run reports the *baseline* plan deliberately.  kimi-k2 "
        "train additionally relies on the bf16 optimizer-state clause "
        "(`opt_state_dtype=bfloat16`, 6 bytes/param instead of 12) and is "
        "the cell that motivates the multi-pod mesh: bytes/device drops "
        "~2x from 16x16 to 2x16x16 (512-way FSDP).\n")
    return "\n".join(lines)


def _roofline_section():
    if not os.path.exists(DRY):
        return "\n## §Roofline\n\n(dryrun_results.json missing)\n"
    with open(DRY) as f:
        res = json.load(f)
    lines = [
        "\n## §Roofline (single-pod 16x16, 256 chips, baseline plans)\n",
        "Terms per the assignment: compute = HLO_FLOPs/(chips x 197e12); "
        "memory = HLO_bytes/(chips x 819e9); collective = per-chip "
        "collective bytes / 50e9.  MODEL_FLOPS = 6ND (train) or 2ND "
        "(inference, N = active params); ratio = MODEL_FLOPS/HLO_FLOPs "
        "(recompute/redundancy waste shows up as ratio < 1).  "
        "roofline_frac = (MODEL_FLOPS/(chips x peak)) / max-term — the "
        "fraction of ideal-compute throughput the cell achieves.\n",
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " MF/HLO | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "compute": "cut remat recompute / raise per-chip work",
        "memory": "Pallas flash kernels (VMEM-resident scores), bf16 reads",
        "collective": "provider switch (less TP), a2a MoE dispatch, SP",
    }
    for key in sorted(res):
        r = res[key]
        if r.get("mesh") != "single":
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"SKIP(full-attn@500k) | - | - | - |")
            continue
        if r["status"] != "ok":
            continue
        c = r["cost"]
        mf = model_flops(get_arch(r["arch"]), get_shape(r["shape"]))
        ratio = mf / max(c["flops"], 1.0)
        ideal = mf / (r["chips"] * 197e12)
        frac = ideal / max(c["total_s"], 1e-12)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {c['compute_s']:.4f} | "
            f"{c['memory_s']:.4f} | {c['collective_s']:.4f} | "
            f"{r['dominant']} | {ratio:.2f} | {frac:.3f} | "
            f"{levers[r['dominant']]} |")
    return "\n".join(lines)


def _perf_section():
    lines = [
        "\n## §Perf — hillclimb log (hypothesis -> change -> before -> "
        "after -> verdict)\n",
        "Four cells hillclimbed (three chosen per the assignment + one "
        "found by the baseline table itself): **A** stablelm-3b x "
        "train_4k (worst dense roofline fraction), **B** qwen3-moe x "
        "train_4k (most collective-bound; the paper-representative case — "
        "the technique's job is exactly to pick the right "
        "provider/dispatch), **C** granite-8b x decode_32k (serving, "
        "memory-bound), **D** starcoder2-3b x train_4k (pathological "
        "outlier: 24 heads / kv=2 divide neither 16-way axis, so TP-style "
        "providers replicate attention 16x — the paper's 'no one compiler "
        "wins everywhere' claim, reproduced quantitatively).  Iteration 0 "
        "of each cell is the **paper-faithful baseline** (best a-priori "
        "single-provider plan); later iterations are ComParX-swept or "
        "beyond-paper changes, labeled.\n\n"
        "The measurement tool itself went through the same "
        "hypothesis->measure->validate loop (archived as "
        "dryrun_results_v{1,2,3}.json): v1 exposed XLA:CPU cost_analysis "
        "ignoring while-loop trip counts (fixed with the call-graph "
        "walker); v2 exposed f32 remat saves from forced f32 dot outputs "
        "(fixed in dense()); v3 exposed CPU float-normalization phantom "
        "converts (546 GB/step on decode) and dus-fusions charging "
        "captured buffers instead of update slices.  Every fix moved the "
        "estimator toward TPU semantics and is unit-tested.\n",
    ]
    if not os.path.exists(PERF):
        lines.append("(perf_results.json missing — run "
                     "benchmarks/perf_iterations.py)")
        return "\n".join(lines)
    with open(PERF) as f:
        res = json.load(f)
    by_cell = {}
    for key, r in res.items():
        cell, name = key.split("/", 1)
        by_cell.setdefault(cell, []).append((name, r))
    for cell in sorted(by_cell):
        rows = sorted(by_cell[cell])
        lines.append(f"\n### Cell {cell}\n")
        lines.append("| iter | hypothesis | compute | memory | collective "
                     "| total | peak/dev | verdict |")
        lines.append("|---|---|---|---|---|---|---|---|")
        prev = None
        for name, r in rows:
            if r["status"] != "ok":
                lines.append(f"| {name} | {r.get('hypothesis','')[:80]} | "
                             f"- | - | - | FAIL | - | "
                             f"{r.get('error','')[:50]} |")
                continue
            c = r["cost"]
            verdict = "baseline"
            if prev is not None:
                gain = prev / max(c["total_s"], 1e-12)
                verdict = (f"CONFIRMED {gain:.2f}x" if gain > 1.05 else
                           ("neutral" if gain > 0.95 else
                            f"REFUTED ({gain:.2f}x)"))
            lines.append(
                f"| {name} | {r.get('hypothesis', '')[:110]} | "
                f"{c['compute_s']:.3f} | {c['memory_s']:.3f} | "
                f"{c['collective_s']:.3f} | **{c['total_s']:.3f}** | "
                f"{c['bytes_per_device']/2**30:.1f} GiB | {verdict} |")
            if name.endswith("baseline") or prev is None or \
                    c["total_s"] < prev:
                prev = c["total_s"]
        lines.append("")
    lines.append("""
**Outcome summary (baseline -> best, the §Perf score):**

| cell | baseline total | best total | gain | winning change |
|---|---|---|---|---|
| A stablelm train  | 6.29 s  | 3.99 s  | **1.57x** | fsdp[shard_both_axes+dp_over_model] (paper-faithful sweep pick) |
| B qwen3-moe train | 36.61 s | 13.47 s | **2.72x** | shard_map a2a expert dispatch (beyond-paper) |
| C granite decode  | 0.039 s | 0.030 s | **1.31x** | bf16 cache reads + shard_map local-dus/LSE decode (beyond-paper) |
| D starcoder train | 40.41 s | 2.78 s  | **14.5x** | provider switch dodging head-divisibility replication (paper-faithful) |

Roofline fractions at the best plans (ideal-term / achieved-total):
A 0.087 of compute roofline (memory-estimator-bound; the modeled Pallas
flash-attention — scores resident in VMEM — removes ~60% of the remaining
memory term); B 0.08 (memory-bound after the collective fix; MoE buffers);
C decode is memory-roofline by nature: ideal = (params+cache reads)/HBM =
4.3 ms vs 29.8 ms achieved = **14% of memory roofline**, with 6.8 GB of
the gap being while-loop carry copies that TPU buffer donation elides;
D 0.12 of compute roofline.  Stopping criterion met per cell: the last
iterations changed the dominant term by <5% (A5, B3, C3-vs-C1 neutral,
D2 refuted).

Paper-faithful vs beyond-paper, explicitly: iterations that only re-pick
providers/flags/knobs from the existing menu (A1-A5, C2, D1, D2) are what
the ComPar sweep itself discovers — the reproduction.  Iterations
introducing new mechanisms the paper's menu lacked (B1 a2a dispatch, C1
bf16 cache reads, C3 shard_map decode, and the Pallas kernels validated
in tests) are the beyond-paper gains, recorded separately as required.

Refuted hypotheses kept on the record (as informative as the wins): A1
(pure FSDP idles the model axis: 16x per-chip FLOPs), A5 (seq-parallel
halves peak memory but its RS+AG pairs cost more than A4's param
gathers), B3 (the MoE combine psum was already a minor term), C2 (batch-
only decode sharding replicates the KV cache 16x), D2 (microbatching
caps the data-parallel degree: batch 64 < 256 chips).
""")
    return "\n".join(lines)


def main():
    doc = HEADER + _dry_section() + _roofline_section() + _perf_section()
    with open(OUT, "w") as f:
        f.write(doc)
    print(f"wrote {OUT} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
