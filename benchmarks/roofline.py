"""Roofline table generator: reads the dry-run results JSON and emits the
EXPERIMENTS §Roofline rows — three terms, dominant bottleneck, MODEL_FLOPS
ratio, and a one-line "what would move the dominant term" note."""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import csv_row
from repro.configs import get_arch, get_shape
from repro.core.cost_model import model_flops

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")

REMEDY = {
    "compute": "increase per-chip work (larger microbatch) or cut remat "
               "recompute",
    "memory": "flash/pallas kernels keep O(S^2)/gate traffic in VMEM; "
              "bf16 intermediates; fewer unfused elementwise chains",
    "collective": "reshard (less TP / more FSDP), sequence parallelism, "
                  "or shard_map all-to-all MoE dispatch",
}


def rows_from_results(path: str = RESULTS,
                      mesh: str = "single") -> List[str]:
    if not os.path.exists(path):
        return [csv_row("roofline/missing", 0.0,
                        f"run launch/dryrun.py first ({path})")]
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, r in sorted(results.items()):
        if r.get("mesh") != mesh:
            continue
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skip":
            rows.append(csv_row(name, 0.0, "SKIP(full-attention@500k)"))
            continue
        if r["status"] != "ok":
            rows.append(csv_row(name, 0.0, f"FAIL:{r.get('error','')[:60]}"))
            continue
        cost = r["cost"]
        mf = model_flops(get_arch(r["arch"]), get_shape(r["shape"]))
        ratio = mf / max(cost["flops"], 1.0)
        total = cost["total_s"]
        # roofline fraction: useful-FLOPs time / achievable step time
        ideal = mf / (r["chips"] * 197e12)
        frac = ideal / max(total, 1e-12)
        rows.append(csv_row(
            name, total * 1e6,
            f"compute={cost['compute_s']:.4f};memory={cost['memory_s']:.4f};"
            f"collective={cost['collective_s']:.4f};dom={r['dominant']};"
            f"model_flops_ratio={ratio:.3f};roofline_frac={frac:.3f};"
            f"bytes_per_dev={cost['bytes_per_device']/2**30:.1f}GiB"))
    return rows


def run(fast: bool = False) -> List[str]:
    return rows_from_results()


if __name__ == "__main__":
    for r in run():
        print(r)
