"""Benchmark entrypoint: one suite per paper table/figure.

  combinations  — Table 1 analogue (swept space + count formula + cost)
  suite_lm      — Fig. 2/3 analogue (provider vs ComPar fusion, wall-clock)
  suite_kernels — Fig. 4/5 analogue (kernel-level comparisons)
  roofline      — EXPERIMENTS §Roofline rows (from the dry-run JSON)

Prints ``name,us_per_call,derived`` CSV.  ``--fast`` trims the slow rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args, _ = ap.parse_known_args()

    from benchmarks import combinations, roofline, suite_kernels, suite_lm
    suites = {
        "combinations": combinations.run,
        "suite_kernels": suite_kernels.run,
        "suite_lm": suite_lm.run,
        "roofline": roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    failed = False
    for name, fn in suites.items():
        try:
            for row in fn(fast=args.fast):
                print(row)
                sys.stdout.flush()
        except Exception as e:
            failed = True
            print(f"{name},0.0,SUITE_ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
