"""Serving throughput: continuous batching vs sequential decoding.

Runs the SAME seeded request stream through one ServeEngine twice —
``max_active=1`` (the sequential one-request-at-a-time baseline) and
full-capacity continuous batching — on the same compiled program, and
reports tok/s for both.  The per-request token streams are asserted
byte-identical between the two runs (the engine's correctness
contract); the speedup is reported, not asserted (CPU smoke timings are
noisy and the win is batching-degree-dependent).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--quick]

Output rows: name,requests,capacity,steps,occupancy,tokens,seconds,tok_s
"""
import argparse

import numpy as np

from repro.configs import get_arch
from repro.core.plan import uniform_plan
from repro.models.context import SegmentClause
from repro.serve import Request, ServeEngine


def _requests(n, vocab, *, tokens, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=f"r{i}",
                    prompt=tuple(int(t)
                                 for t in rng.randint(0, vocab,
                                                      2 + i % 3)),
                    max_new_tokens=tokens + i % 4)
            for i in range(n)]


def row(name, stats, n_requests):
    print(f"{name},{n_requests},{stats.capacity},{stats.n_steps},"
          f"{stats.occupancy:.2f},{stats.n_tokens},"
          f"{stats.elapsed_s:.3f},{stats.tok_s:.1f}")
    return stats


def main(quick: bool = False, arch: str = "stablelm-3b"):
    cfg = get_arch(arch).smoke()
    plan = uniform_plan(cfg, "tensor_par", set(),
                        SegmentClause(remat="none", kernel="xla"))
    capacity = 4 if quick else 8
    n_req, tokens = (8, 6) if quick else (24, 16)
    engine = ServeEngine(cfg, plan, capacity=capacity,
                         cache_len=32 if quick else 64)
    reqs = _requests(n_req, cfg.vocab_size, tokens=tokens)

    # warm both compiled paths (prefill retraces per prompt length)
    engine.run(reqs[:capacity])

    print("name,requests,capacity,steps,occupancy,tokens,seconds,tok_s")
    seq = engine.run(reqs, max_active=1)
    s_seq = row("serve-sequential", engine.stats, n_req)
    bat = engine.run(reqs)
    s_bat = row("serve-batched", engine.stats, n_req)

    for r in reqs:
        assert bat[r.rid].tokens == seq[r.rid].tokens, \
            f"stream diverged for {r.rid}"
    assert s_bat.peak_active > 1 and s_seq.peak_active == 1
    assert s_bat.n_steps < s_seq.n_steps       # batching collapses steps
    print(f"# streams byte-identical; speedup x{s_bat.tok_s / s_seq.tok_s:.2f} "
          f"(steps {s_seq.n_steps} -> {s_bat.n_steps})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="stablelm-3b")
    main(**vars(ap.parse_args()))
