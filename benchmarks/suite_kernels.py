"""Fig. 4/5 analogue — the "PolyBench suite" of ComParX: compute-kernel
level comparisons.

Wall-clock rows compare real, jitted XLA implementations (CPU).  The
Pallas TPU kernels execute here only in interpret mode (CPU container), so
their rows report the *analytic HBM-traffic model* (the quantity the
roofline optimizes on the TPU target) next to an interpret-mode allclose
check — honest labels, no fake wall-clocks.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_jitted
from repro.kernels import ref
from repro.models.attention import chunked_attention, naive_attention
from repro.models.rglru import rglru_scan


def _attention_rows() -> List[str]:
    rows = []
    B, S, H, KV, D = 2, 1024, 8, 2, 64
    q = jax.random.normal(jax.random.key(1), (B, S, H, D))
    k = jax.random.normal(jax.random.key(2), (B, S, KV, D))
    v = jax.random.normal(jax.random.key(3), (B, S, KV, D))
    pos = jnp.arange(S)
    naive = jax.jit(lambda q, k, v: naive_attention(
        q, k, v, pos_q=pos, pos_k=pos))
    chunked = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, pos_q=pos, pos_k=pos, q_chunk=128))
    tn = time_jitted(naive, (q, k, v))
    tc = time_jitted(chunked, (q, k, v))
    rows.append(csv_row("kernels/attention/naive_xla", tn * 1e6,
                        "speedup=1.00"))
    rows.append(csv_row("kernels/attention/chunked_xla", tc * 1e6,
                        f"speedup={tn / tc:.2f}"))
    # Pallas flash attention: HBM-traffic model + interpret allclose
    hbm_naive = B * H * S * S * 4 * 2 + B * S * (H + 2 * KV) * D * 4
    hbm_flash = B * S * (2 * H + 2 * KV) * D * 4   # scores stay in VMEM
    out = __import__("repro.kernels.ops", fromlist=["x"]).flash_attention(
        q[:, :256], k[:, :256], v[:, :256], block_q=128, block_k=128)
    expect = chunked_attention(q[:, :256], k[:, :256], v[:, :256],
                               pos_q=pos[:256], pos_k=pos[:256],
                               q_chunk=128)
    err = float(jnp.max(jnp.abs(out - expect)))
    rows.append(csv_row(
        "kernels/attention/pallas_flash", 0.0,
        f"hbm_bytes_model={hbm_flash};vs_naive={hbm_naive / hbm_flash:.1f}x"
        f";interpret_max_err={err:.2e}"))
    return rows


def _rglru_rows() -> List[str]:
    rows = []
    B, S, dr = 4, 2048, 256
    la = -jnp.abs(jax.random.normal(jax.random.key(1), (B, S, dr))) * 0.1
    b = jax.random.normal(jax.random.key(2), (B, S, dr))

    assoc = jax.jit(lambda la, b: rglru_scan(jnp.exp(la), b))

    def step_scan(la, b):
        def f(h, ab):
            a_t, b_t = ab
            h = a_t * h + b_t
            return h, h
        _, hs = jax.lax.scan(f, jnp.zeros((B, dr)),
                             (jnp.moveaxis(jnp.exp(la), 1, 0),
                              jnp.moveaxis(b, 1, 0)))
        return jnp.moveaxis(hs, 0, 1)

    stepped = jax.jit(step_scan)
    ta = time_jitted(assoc, (la, b))
    ts = time_jitted(stepped, (la, b))
    rows.append(csv_row("kernels/rglru/step_scan_xla", ts * 1e6,
                        "speedup=1.00"))
    rows.append(csv_row("kernels/rglru/assoc_scan_xla", ta * 1e6,
                        f"speedup={ts / ta:.2f}"))
    from repro.kernels import ops
    out = ops.rglru(la[:1, :256], b[:1, :256], chunk=64)
    expect = ref.rglru_ref(la[:1, :256], b[:1, :256])
    err = float(jnp.max(jnp.abs(out - expect)))
    rows.append(csv_row("kernels/rglru/pallas_blocked", 0.0,
                        f"interpret_max_err={err:.2e};"
                        "vmem_matrix_form=chunk^2xD"))
    return rows


def _mlstm_rows() -> List[str]:
    rows = []
    B, H, S, dh = 2, 4, 512, 64
    q = jax.random.normal(jax.random.key(1), (B, H, S, dh)) * dh ** -0.5
    k = jax.random.normal(jax.random.key(2), (B, H, S, dh))
    v = jax.random.normal(jax.random.key(3), (B, H, S, dh))
    li = jax.random.normal(jax.random.key(4), (B, H, S))
    lf = -jax.nn.softplus(-jax.random.normal(jax.random.key(5), (B, H, S)))

    recurrent = jax.jit(lambda *a: ref.mlstm_ref(*a))

    from repro.kernels.ops import mlstm_chunkwise

    def chunkwise_jnp(q, k, v, li, lf):
        from repro.models.xlstm import mlstm_chunk
        c = 128
        nc = S // c
        rs = lambda t: jnp.moveaxis(
            t.reshape(*t.shape[:2], nc, c, *t.shape[3:]), 2, 0)
        st = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
              jnp.zeros((B, H)))
        def stp(s, inp):
            h, ns = mlstm_chunk(*inp, s)
            return ns, h
        _, hs = jax.lax.scan(stp, st, (rs(q), rs(k), rs(v), rs(li), rs(lf)))
        return jnp.moveaxis(hs, 0, 2).reshape(B, H, S, dh)

    cw = jax.jit(chunkwise_jnp)
    tr = time_jitted(recurrent, (q, k, v, li, lf))
    tc = time_jitted(cw, (q, k, v, li, lf))
    rows.append(csv_row("kernels/mlstm/recurrent_xla", tr * 1e6,
                        "speedup=1.00"))
    rows.append(csv_row("kernels/mlstm/chunkwise_xla", tc * 1e6,
                        f"speedup={tr / tc:.2f}"))
    out = mlstm_chunkwise(q[:1, :1, :128], k[:1, :1, :128],
                          v[:1, :1, :128], li[:1, :1, :128],
                          lf[:1, :1, :128], chunk=32)
    expect = ref.mlstm_ref(q[:1, :1, :128], k[:1, :1, :128],
                           v[:1, :1, :128], li[:1, :1, :128],
                           lf[:1, :1, :128])
    err = float(jnp.max(jnp.abs(out - expect)))
    rows.append(csv_row("kernels/mlstm/pallas_chunkwise", 0.0,
                        f"interpret_max_err={err:.2e}"))
    return rows


def run(fast: bool = False) -> List[str]:
    rows = _attention_rows() + _rglru_rows()
    if not fast:
        rows += _mlstm_rows()
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
