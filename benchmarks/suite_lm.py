"""Fig. 2/3 analogue — the "NAS suite" of ComParX.

The paper times 6 NAS benchmarks under each S2S compiler and under ComPar's
fusion, against the serial baseline.  Here: 6 assigned architectures
(reduced configs, real CPU wall-clock) under each strategy provider
(uniform plan), under an untuned default ("serial" analogue: worst clause,
no sweep), and under the ComParX fused plan.  Reports speedups; asserts
the paper's guarantee (fused >= best single provider).
"""
from __future__ import annotations

from typing import Dict, List

import jax

from benchmarks.common import csv_row, time_jitted
from repro.configs import get_arch, get_shape
from repro.core import ComParTuner
from repro.core.combinator import GlobalKnobs
from repro.core.executor import CombinationFailed
from repro.core.plan import Plan, uniform_plan
from repro.models.context import SegmentClause
from repro.train.step import init_train_state, jit_train_step

# timing re-runs the step from the same buffers -> no donation
NO_DONATE = GlobalKnobs(donate=False)

BENCH_ARCHS = ["stablelm-3b", "granite-8b", "chatglm3-6b",
               "starcoder2-3b", "xlstm-125m", "recurrentgemma-2b"]

SWEEP_SPACE = {"remat": ("none", "dots"), "kernel": ("xla",),
               "block_q": (16,), "block_k": (16,), "scan_unroll": (1,),
               "mlstm_chunk": (16,)}

#: the "serial" analogue: what you get with no tuning at all
SERIAL_CLAUSE = SegmentClause(remat="full", kernel="xla", block_q=8,
                              block_k=8, mlstm_chunk=8)


def _step_time(cfg, plan: Plan) -> float:
    step, _ = jit_train_step(cfg, None, plan)
    params, opt = init_train_state(cfg, plan, jax.random.key(0))
    from repro.data.pipeline import SyntheticLM
    shape = get_shape("train_4k").smoke()
    batch = SyntheticLM(cfg, shape, seed=0).batch_at(0)
    return time_jitted(step, (params, opt, batch), repeats=3)


def run(fast: bool = False) -> List[str]:
    rows: List[str] = []
    archs = BENCH_ARCHS[:3] if fast else BENCH_ARCHS
    shape = get_shape("train_4k").smoke()
    for arch in archs:
        cfg = get_arch(arch).smoke()
        serial_t = _step_time(cfg, uniform_plan(
            cfg, "fsdp", clause=SERIAL_CLAUSE, knobs=NO_DONATE))
        times: Dict[str, float] = {}
        for prov in ("tensor_par", "fsdp"):
            try:
                times[prov] = _step_time(cfg, uniform_plan(
                    cfg, prov, clause=SegmentClause(remat="none"),
                    knobs=NO_DONATE))
            except CombinationFailed:
                times[prov] = float("inf")
        tuner = ComParTuner(cfg, shape, mesh=None, executor="wallclock",
                            project=f"bench-{arch}", timeout_s=180)
        fused_plan, rep = tuner.sweep(providers=["tensor_par", "fsdp"],
                                      clause_space=SWEEP_SPACE,
                                      max_flags=0, knobs=NO_DONATE)
        fused_t = _step_time(cfg, fused_plan)
        best_single = min(times.values())
        rows.append(csv_row(
            f"lm_suite/{arch}/serial", serial_t * 1e6, "speedup=1.00"))
        for prov, t in times.items():
            rows.append(csv_row(f"lm_suite/{arch}/{prov}", t * 1e6,
                                f"speedup={serial_t / t:.2f}"))
        rows.append(csv_row(
            f"lm_suite/{arch}/compar_fused", fused_t * 1e6,
            f"speedup={serial_t / fused_t:.2f};"
            f"vs_best_single={best_single / fused_t:.2f};"
            f"combos={rep.n_done}"))
        # ComPar's guarantee comes from single-provider outputs being IN
        # the candidate set: the Optimal Code Generator measures the
        # finalists end-to-end and emits whichever is fastest (worst case
        # = the best single compiler's output, paper section 4.1).
        final_t = min(fused_t, best_single)
        winner = "fused" if fused_t <= best_single else "best_uniform"
        rows.append(csv_row(
            f"lm_suite/{arch}/compar_final", final_t * 1e6,
            f"speedup={serial_t / final_t:.2f};"
            f"vs_best_single={best_single / final_t:.2f};"
            f"winner={winner}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
