"""Sweep-engine throughput: the cost of the sweep itself.

Measures combos/sec of three engine settings on one smoke registry config:

  seed-style   workers=1, no cache, no prune, one commit per row
  engine-cold  workers=N + structural sharing + prune + batched I/O,
               empty persistent cache
  engine-warm  same engine, second sweep against the populated cache
               (must recompile NOTHING)

With ``--backend process`` (or ``both``) an ``engine-cold-process`` row is
added: the same cold engine on the spawned-worker process backend (true
parallel tracing past the GIL + hard preemptive timeouts) — thread rows
are always reported alongside, so backend numbers stay comparable.

With ``--backend remote`` (or ``both``) a loopback sweep scoring server
is started (``repro.core.backends.server``) and two rows are added:
``engine-cold-remote`` (fresh server cache — every unique program
compiles once, server-side) and ``engine-warm-remote`` (a *different*
client with an empty local DB against the now-warm server).  The warm
run asserts ZERO server-side compiles — the cross-host amortization
story, measured.

With ``--globals`` an ``engine-cold-knobaxis2x`` row sweeps a 2-point
*non-reaching* GlobalKnobs axis (``opt_state_dtype``): twice the rows,
and the run asserts the engine compiled nothing extra — the knob-
relevance projection makes the outer axis ~free.

With ``--chaos`` an ``engine-cold-chaos`` row runs the remote sweep
through a fault-injecting proxy (``repro.core.backends.faults``) that
drops, truncates, and 5xx-es replies on a seeded schedule — the row
prices the retry machinery and asserts the fused plan is still
byte-identical with zero failed rows (robustness is an optimization
detail, not an approximation).

With ``--calibrated`` two rows price the calibrated machine model
(``repro.core.machine``) on a compute-dominated pruning scenario
(recurrentgemma train, remat none-vs-full, ``prune_margin=0``):
``prune-const-hw`` scores against the shipped V5E constants,
``prune-calibrated-hw`` against a pinned slow-host profile whose
tightened compute floor lets the bound clear the incumbent.  The
calibrated row must prune strictly more and compile strictly less, and
BOTH rows must fuse plans byte-identical to their own unpruned
references — harder pruning, still exact.

With ``--kernel-axis`` two rows price the hierarchical kernel-schedule
autotuner (``repro.kernels.autotune``): an 8-point tile/variant grid
(``kernel`` x ``block_q`` x ``block_k``) is timed in isolation and only
the top-2 surviving schedules per segment enter the outer
cross-product.  ``engine-cold-kernelaxis`` asserts the outer compile
count grows by at most 2 combos per affected segment over the no-axis
baseline AND that pruning with the kernel-aware floor fuses the plan
byte-identical to its unpruned reference; ``engine-warm-kernelaxis``
re-runs against the populated ``kernel_cache`` and asserts ZERO kernel
re-benchmarks and ZERO outer recompiles.

With ``--static`` two rows price the static analyzer
(``repro.analysis``) on a space seeded with provably-invalid points:
``invalid-space-lint-off`` dispatches every point (the bad ones each
cost a compile attempt and land as ``failed`` rows),
``invalid-space-lint-strict`` rejects them pre-dispatch as ``static``
rows.  The strict row must reject a nonzero number of points, strictly
reduce failed dispatches, and fuse a plan byte-identical to the
unlinted run — the lint only ever removes points the compiler would
have rejected anyway.

With ``--mesh-space`` two rows sweep the topology axis
(``mesh_space=[local, data2]`` — ``data1`` on single-device hosts) on
the *selected* backend: ``engine-cold-meshaxis2x`` and
``engine-warm-meshaxis2x``.  The warm row asserts ZERO recompiles (the
per-point cache keys hit) and both fuse the same plan with the same
CHOSEN mesh — multi-device sweeps through the declarative MeshSpec wire
format, on whatever backend ``--backend`` picked (including process and
remote: the old thread-only fallback for meshed sweeps is gone).

Asserts the fused plans of all runs are identical (the engine is an
optimization, not an approximation) and reports speedups vs seed-style.

  PYTHONPATH=src python benchmarks/sweep_throughput.py [--quick]
      [--arch granite-8b] [--shape train_4k] [--workers N]
      [--backend thread|process|remote|both] [--assert-speedup X]
      [--globals] [--chaos] [--mesh-space] [--calibrated] [--kernel-axis]
      [--static]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time


def _sweep(db, project, cfg, shape, space, **kw):
    from repro.core.tuner import ComParTuner
    tuner = ComParTuner(cfg, shape, mesh=None, db=db, project=project,
                        mode="new", executor="dryrun", timeout_s=300)
    t0 = time.perf_counter()
    plan, rep = tuner.sweep(providers=["tensor_par", "fsdp", "hybrid2d"],
                            clause_space=space, max_flags=1, **kw)
    return plan, rep, time.perf_counter() - t0


def run(quick: bool = False, arch: str = "granite-8b",
        shape_name: str = "train_4k", workers: int = 0,
        backend: str = "thread", assert_speedup: float = 0.0,
        globals_axis: bool = False, mesh_axis: bool = False,
        chaos: bool = False, calibrated: bool = False,
        kernel_axis: bool = False, static: bool = False):
    from repro.configs import get_arch, get_shape
    from repro.core.db import SweepDB

    cfg = get_arch(arch).smoke()
    shape = get_shape(shape_name).smoke()
    workers = workers or min(8, os.cpu_count() or 1)
    space = {"remat": ("none", "full"), "kernel": ("xla",),
             "block_q": (16,), "block_k": (16,),
             "scan_unroll": (1,), "mlstm_chunk": (16,)} if quick else \
            {"remat": ("none", "dots", "full"), "kernel": ("xla",),
             "block_q": (16, 32), "block_k": (16, 32),
             "scan_unroll": (1,), "mlstm_chunk": (16,)}

    tmp = tempfile.mkdtemp(prefix="sweep_bench_")
    try:
        # warm jax/compile caches once so the baseline isn't charged for
        # first-touch initialization the engine runs would then skip
        _sweep(SweepDB(":memory:"), "warmup", cfg, shape,
               {k: (v[0],) for k, v in space.items()},
               workers=1, use_cache=False, prune=False)

        plan0, rep0, t_seed = _sweep(
            SweepDB(os.path.join(tmp, "seed.db")), "seed", cfg, shape, space,
            workers=1, use_cache=False, prune=False, share_scores=False,
            record_batch=1)

        db = SweepDB(os.path.join(tmp, "engine.db"))
        plan1, rep1, t_cold = _sweep(
            db, "cold", cfg, shape, space,
            workers=workers, use_cache=True, prune=True)
        plan2, rep2, t_warm = _sweep(
            db, "warm", cfg, shape, space,
            workers=workers, use_cache=True, prune=True)

        assert plan1.segments == plan0.segments, "engine changed the plan!"
        assert plan2.segments == plan0.segments, "warm sweep changed the plan!"
        assert rep2.n_scored == 0, "warm sweep recompiled something"
        # pruned outcomes are deliberately never cached (they are relative
        # to a project's incumbent); a warm sweep re-prunes them from
        # cache-seeded incumbents without compiling
        assert rep2.n_cached + rep2.n_pruned == rep2.n_combinations, \
            (f"cache hits {rep2.n_cached} + pruned {rep2.n_pruned} "
             f"!= combos {rep2.n_combinations}")

        n = rep0.n_combinations
        rows = [
            ("seed-style", t_seed, rep0),
            ("engine-cold", t_cold, rep1),
            ("engine-warm", t_warm, rep2),
        ]
        if backend in ("process", "both"):
            plan3, rep3, t_proc = _sweep(
                SweepDB(os.path.join(tmp, "proc.db")), "proc", cfg, shape,
                space, backend="process", workers=workers,
                use_cache=True, prune=True)
            assert plan3.segments == plan0.segments, \
                "process backend changed the plan!"
            rows.append(("engine-cold-process", t_proc, rep3))

        if backend in ("remote", "both"):
            import json
            import urllib.request

            from repro.core.backends.server import SweepScoringServer

            def stats(url):
                with urllib.request.urlopen(url + "/v1/stats",
                                            timeout=10) as r:
                    return json.loads(r.read())

            srv = SweepScoringServer(os.path.join(tmp, "remote-server.db"),
                                     workers=workers)
            url = srv.start()
            try:
                # clients keep their local cache OFF so every score comes
                # over the wire — the rows measure the server's cache
                plan5, rep5, t_rcold = _sweep(
                    SweepDB(os.path.join(tmp, "rem1.db")), "rem-cold", cfg,
                    shape, space, backend="remote", remote_url=url,
                    use_cache=False, prune=True)
                assert plan5.segments == plan0.segments, \
                    "remote backend changed the plan!"
                s_cold = stats(url)
                assert s_cold["n_compiled"] == rep5.n_scored > 0
                plan6, rep6, t_rwarm = _sweep(
                    SweepDB(os.path.join(tmp, "rem2.db")), "rem-warm", cfg,
                    shape, space, backend="remote", remote_url=url,
                    use_cache=False, prune=True)
                assert plan6.segments == plan0.segments, \
                    "warm remote sweep changed the plan!"
                s_warm = stats(url)
                assert s_warm["n_compiled"] == s_cold["n_compiled"], \
                    (f"cache-warm remote sweep compiled server-side: "
                     f"{s_warm['n_compiled']} vs {s_cold['n_compiled']}")
                assert rep6.n_scored == 0, \
                    "warm remote sweep recompiled something"
                rows.append(("engine-cold-remote", t_rcold, rep5))
                rows.append(("engine-warm-remote", t_rwarm, rep6))
            finally:
                srv.close()

        if chaos:
            from repro.core.backends import (ChaosProxy, FaultPlan,
                                             FaultRule, RetryPolicy)
            from repro.core.backends.faults import DROP, ERROR, TRUNCATE
            from repro.core.backends.server import SweepScoringServer

            plan_fp = FaultPlan({"proxy": (
                FaultRule(DROP, rate=0.10),
                FaultRule(TRUNCATE, rate=0.05),
                FaultRule(ERROR, rate=0.05, status=503),
            )}, seed=1234)
            csrv = SweepScoringServer(os.path.join(tmp, "chaos-server.db"),
                                      workers=workers)
            proxy = ChaosProxy(csrv.start(), plan_fp)
            try:
                plan9, rep9, t_chaos = _sweep(
                    SweepDB(os.path.join(tmp, "chaos.db")), "chaos", cfg,
                    shape, space, backend="remote",
                    remote_url=proxy.start(), use_cache=False, prune=True,
                    retry=RetryPolicy(budget_s=60.0, base_s=0.05,
                                      cap_s=0.5))
            finally:
                proxy.close()
                csrv.close()
            assert plan9.segments == plan0.segments, \
                "chaos sweep changed the plan!"
            assert rep9.n_failed == 0 and rep9.n_transient == 0, \
                (f"chaos sweep lost rows: failed={rep9.n_failed} "
                 f"transient={rep9.n_transient}")
            print(f"# chaos: {len(plan_fp.events)} faults injected "
                  f"({sum(1 for *_, k in plan_fp.events if k == DROP)} drop, "
                  f"{sum(1 for *_, k in plan_fp.events if k == TRUNCATE)} "
                  f"truncate, "
                  f"{sum(1 for *_, k in plan_fp.events if k == ERROR)} 5xx)")
            rows.append(("engine-cold-chaos", t_chaos, rep9))

        if globals_axis:
            # the knob axis: 2x the rows (a swept non-reaching knob),
            # same number of compiles — the axis must be ~free
            plan4, rep4, t_knob = _sweep(
                SweepDB(os.path.join(tmp, "knob.db")), "knob", cfg, shape,
                space, workers=workers, use_cache=True, prune=True,
                global_space={"opt_state_dtype": ("float32", "bfloat16")})
            assert plan4.segments == plan0.segments, \
                "knob axis changed the per-segment plan!"
            assert rep4.n_scored == rep1.n_scored, \
                (f"non-reaching knob axis recompiled: {rep4.n_scored} "
                 f"vs {rep1.n_scored}")
            rows.append(("engine-cold-knobaxis2x", t_knob, rep4))

        if calibrated:
            # the calibrated machine model vs the V5E constants, on the
            # scenario where the remat compute floor actually bites: a
            # pinned compute-dominated profile (peak ~1 GFLOP/s, so the
            # compute term dominates memory/collective on any host —
            # deterministic, no live microbenchmark noise) tightens the
            # bound enough that remat=full is pruned without compiling.
            # Each variant checks against its own unpruned reference in
            # the same DB (the ref resolves from cache, compiling 0).
            from repro.core.machine import MachineProfile
            ccfg = get_arch("recurrentgemma-2b").smoke()
            cshape = get_shape("train_4k").smoke()
            cspace = {"remat": ("none", "full"), "kernel": ("xla",),
                      "block_q": (16,), "block_k": (16,),
                      "scan_unroll": (1,), "mlstm_chunk": (16,)}
            slow = MachineProfile(platform="synthetic",
                                  device_kind="slow-host", n_devices=1,
                                  peak_flops={"bfloat16": 1.0e9})

            def _cal_sweep(project, machine, prune):
                from repro.core.tuner import ComParTuner
                cdb = SweepDB(os.path.join(tmp, f"cal-{project}.db"))
                t0 = time.perf_counter()
                out = []
                for prj, prn in ((project, prune), (f"{project}-ref", False)):
                    tuner = ComParTuner(ccfg, cshape, mesh=None, db=cdb,
                                        project=prj, mode="new",
                                        executor="dryrun", timeout_s=300,
                                        machine=machine)
                    out.append(tuner.sweep(
                        providers=["fsdp"], clause_space=cspace,
                        max_flags=0, workers=1, use_cache=True,
                        prune=prn, prune_margin=0.0))
                (planp, repp), (planr, _) = out
                assert planp.segments == planr.segments, \
                    f"pruning changed the plan under machine={machine!r}"
                return planp, repp, time.perf_counter() - t0

            planc, repc, t_cconst = _cal_sweep("cal-const", None, True)
            plans, reps, t_ccal = _cal_sweep("cal-slow", slow, True)
            assert plans.segments == planc.segments, \
                "the machine model changed the fused plan!"
            assert reps.n_pruned > repc.n_pruned, \
                (f"calibrated model pruned no harder: {reps.n_pruned} "
                 f"vs {repc.n_pruned}")
            assert reps.n_scored < repc.n_scored, \
                (f"calibrated model skipped no compiles: {reps.n_scored} "
                 f"vs {repc.n_scored}")
            print(f"# calibrated: pruned {reps.n_pruned} vs "
                  f"{repc.n_pruned} const, compiled {reps.n_scored} vs "
                  f"{repc.n_scored} const, plans identical")
            rows.append(("prune-const-hw", t_cconst, repc))
            rows.append(("prune-calibrated-hw", t_ccal, reps))

        if kernel_axis:
            # the hierarchical kernel-schedule axis: an 8-point
            # tile/variant grid tuned in isolation; only the top-2
            # surviving schedules per segment reach the cross-product.
            # Baseline is the same single-point space with no axis, in
            # its own DB so compile counts are directly comparable.
            kbase = {"remat": ("none",), "kernel": ("xla",),
                     "block_q": (16,), "block_k": (16,),
                     "scan_unroll": (1,), "mlstm_chunk": (16,)}
            kgrid = {"kernel": ("xla", "pallas"), "block_q": (16, 32),
                     "block_k": (16, 32)}
            planb, repb, _ = _sweep(
                SweepDB(os.path.join(tmp, "kernel-base.db")), "kernel-base",
                cfg, shape, kbase, workers=workers, use_cache=True,
                prune=True)
            kdb = SweepDB(os.path.join(tmp, "kernel.db"))
            plank, repk, t_kcold = _sweep(
                kdb, "kernel-cold", cfg, shape, kbase, workers=workers,
                use_cache=True, prune=True, kernel_space=kgrid,
                kernel_top_k=2)
            kt = repk.kernel_tuning
            n_aff = sum(1 for d in kt["per_segment"].values()
                        if d["kept"] < d["schedules"])
            assert kt["n_variants"] >= 6 and kt["top_k"] == 2
            assert repk.n_scored <= repb.n_scored + 2 * n_aff, \
                (f"kernel axis over-compiled: {repk.n_scored} vs base "
                 f"{repb.n_scored} + 2 x {n_aff} affected segments")
            # exactness: pruning with the kernel-aware floor fuses the
            # same plan as the unpruned reference (cache makes it cheap)
            planr, _, _ = _sweep(
                kdb, "kernel-ref", cfg, shape, kbase, workers=workers,
                use_cache=True, prune=False, kernel_space=kgrid,
                kernel_top_k=2)
            assert plank.segments == planr.segments, \
                "kernel-aware pruning changed the plan!"
            plankw, repkw, t_kwarm = _sweep(
                kdb, "kernel-warm", cfg, shape, kbase, workers=workers,
                use_cache=True, prune=True, kernel_space=kgrid,
                kernel_top_k=2)
            assert repkw.kernel_tuning["n_timed"] == 0, \
                "warm kernel_cache re-benchmarked a schedule"
            assert repkw.n_scored == 0, \
                "warm kernel-axis sweep recompiled something"
            assert plankw.segments == plank.segments, \
                "warm kernel-axis sweep changed the plan!"
            print(f"# kernel axis: {kt['n_variants']} schedules "
                  f"({kt['n_timed']} timed, {kt['n_cached']} cached), "
                  f"top-2 kept on {n_aff} segment(s), compiles "
                  f"{repk.n_scored} vs {repb.n_scored} base, "
                  f"best {kt['per_op_best']}")
            rows.append(("engine-cold-kernelaxis", t_kcold, repk))
            rows.append(("engine-warm-kernelaxis", t_kwarm, repkw))

        if static:
            # the static analyzer as a throughput lever: a space seeded
            # with provably-invalid points (pallas block_q=24 on S=32,
            # microbatches=3 on B=4) swept with checks off (every bad
            # point costs a compile attempt -> failed row) vs strict
            # (rejected pre-dispatch as "static" rows, zero compile
            # attempts).  Same project name in both DBs so the fused
            # plans — meta included — must be byte-identical: the lint
            # only ever removes points the compiler would have rejected.
            import json as _json
            sspace = {"remat": ("none",), "kernel": ("xla", "pallas"),
                      "block_q": (16, 24), "block_k": (32,),
                      "scan_unroll": (1,), "mlstm_chunk": (16,)}
            sglobals = {"microbatches": (1, 3)}
            plan_off, rep_off, t_soff = _sweep(
                SweepDB(os.path.join(tmp, "static-off.db")), "static",
                cfg, shape, sspace, workers=workers, use_cache=True,
                global_space=sglobals, static_checks="off")
            plan_st, rep_st, t_strict = _sweep(
                SweepDB(os.path.join(tmp, "static-strict.db")), "static",
                cfg, shape, sspace, workers=workers, use_cache=True,
                global_space=sglobals, static_checks="strict")
            assert rep_st.n_static > 0, \
                "strict linting rejected nothing on a seeded-invalid space"
            assert rep_st.n_failed < rep_off.n_failed, \
                (f"strict did not reduce dispatched failures: "
                 f"{rep_st.n_failed} vs {rep_off.n_failed}")
            assert _json.dumps(plan_st.to_json(), sort_keys=True) == \
                _json.dumps(plan_off.to_json(), sort_keys=True), \
                "static checks changed the fused plan!"
            print(f"# static: {rep_st.n_static} points rejected "
                  f"pre-dispatch ({dict(sorted(rep_st.static_rules.items()))}),"
                  f" failed {rep_off.n_failed} -> {rep_st.n_failed}, "
                  f"plan byte-identical")
            rows.append(("invalid-space-lint-off", t_soff, rep_off))
            rows.append(("invalid-space-lint-strict", t_strict, rep_st))

        if mesh_axis:
            # the topology axis, on the SELECTED backend: cold sweeps
            # both mesh points (MeshSpec wire format — process/remote
            # workers rebuild the mesh themselves), warm recompiles
            # nothing and fuses the identical plan + chosen mesh
            import jax
            mspace = [None, {"data": min(2, len(jax.devices()))}]
            mkw = {"backend": backend if backend != "both" else "process",
                   "workers": workers}
            msrv = None
            if mkw["backend"] == "remote":
                from repro.core.backends.server import SweepScoringServer
                msrv = SweepScoringServer(
                    os.path.join(tmp, "mesh-server.db"), workers=workers)
                mkw["remote_url"] = msrv.start()
            try:
                mdb = SweepDB(os.path.join(tmp, "mesh.db"))
                plan7, rep7, t_mcold = _sweep(
                    mdb, "mesh-cold", cfg, shape, space, use_cache=True,
                    prune=True, mesh_space=mspace, **mkw)
                plan8, rep8, t_mwarm = _sweep(
                    mdb, "mesh-warm", cfg, shape, space, use_cache=True,
                    prune=True, mesh_space=mspace, **mkw)
            finally:
                if msrv is not None:
                    msrv.close()
            assert rep7.n_mesh_points == rep8.n_mesh_points == 2
            assert plan7.mesh is not None, "no mesh was chosen"
            assert (plan8.segments, plan8.knobs, plan8.mesh) == \
                (plan7.segments, plan7.knobs, plan7.mesh), \
                "warm mesh-axis sweep changed the plan!"
            assert rep8.n_scored == 0, \
                (f"warm mesh-axis sweep recompiled {rep8.n_scored} "
                 "programs (per-point cache keys missed)")
            print(f"# mesh axis: chosen {plan7.mesh.key()} of "
                  f"{list(rep7.per_mesh_total_s)} "
                  f"(backend={mkw['backend']})")
            rows.append(("engine-cold-meshaxis2x", t_mcold, rep7))
            rows.append(("engine-warm-meshaxis2x", t_mwarm, rep8))
        print(f"# arch={cfg.name} shape={shape.name} combos={n} "
              f"workers={workers} backend={backend} quick={quick}")
        print("name,combos_per_s,seconds,scored,cached,pruned,speedup_vs_seed")
        for name, t, rep in rows:
            print(f"{name},{rep.n_combinations / t:.1f},{t:.2f},"
                  f"{rep.n_scored},{rep.n_cached},{rep.n_pruned},"
                  f"{t_seed / t:.2f}x")
        if assert_speedup:
            assert t_seed / t_cold >= assert_speedup, \
                f"cold speedup {t_seed / t_cold:.2f}x < {assert_speedup}x"
        return t_seed / t_cold, t_seed / t_warm
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "process", "remote", "both"))
    ap.add_argument("--assert-speedup", type=float, default=0.0)
    ap.add_argument("--globals", dest="globals_axis", action="store_true",
                    help="add a 2-point non-reaching GlobalKnobs axis row "
                         "(2x rows, must compile nothing extra)")
    ap.add_argument("--chaos", action="store_true",
                    help="add an engine-cold-chaos row: the remote sweep "
                         "through a seeded fault-injecting proxy "
                         "(drops/truncations/5xx); asserts the plan stays "
                         "byte-identical with zero failed rows")
    ap.add_argument("--calibrated", action="store_true",
                    help="add prune-const-hw / prune-calibrated-hw rows: "
                         "the same pruning sweep under the V5E constants "
                         "vs a pinned slow-host machine profile; the "
                         "calibrated row must prune strictly more, compile "
                         "strictly less, and fuse the identical plan")
    ap.add_argument("--kernel-axis", dest="kernel_axis",
                    action="store_true",
                    help="add cold+warm kernel-schedule axis rows: an "
                         "8-point tile/variant grid tuned in isolation, "
                         "top-2 schedules per segment; cold asserts <= 2 "
                         "extra compiles per affected segment and exact "
                         "pruning, warm asserts zero re-benchmarks")
    ap.add_argument("--mesh-space", dest="mesh_axis", action="store_true",
                    help="add cold+warm 2-point mesh/topology axis rows on "
                         "the selected backend (warm must recompile "
                         "nothing); multi-device points need "
                         "XLA_FLAGS=--xla_force_host_platform_device_count")
    ap.add_argument("--static", action="store_true",
                    help="add invalid-space-lint-off/-strict rows: a sweep "
                         "seeded with provably-invalid points run with "
                         "static checks off vs strict; strict must reject "
                         "points pre-dispatch (n_static>0), reduce failed "
                         "rows, and fuse the byte-identical plan")
    args = ap.parse_args()
    run(quick=args.quick, arch=args.arch, shape_name=args.shape,
        workers=args.workers, backend=args.backend,
        assert_speedup=args.assert_speedup, globals_axis=args.globals_axis,
        mesh_axis=args.mesh_axis, chaos=args.chaos,
        calibrated=args.calibrated, kernel_axis=args.kernel_axis,
        static=args.static)


if __name__ == "__main__":
    main()
