"""ComPar-style JSON-driven sweep (the paper's three-JSON input UX) with
DB Continue mode: run once, kill it, run again — finished combinations
are not re-executed.

The ``"globals"`` field is the paper's RTL-routine axis: a GlobalKnobs
grid swept as an outer dimension of the same sweep, with the fused
plan's knobs chosen by the joint argmin (see docs/sweep_engine.md).

With ``--remote-url`` the scoring leaves this host entirely: jobs are
shipped to a sweep scoring server
(``python -m repro.core.backends.server --db scores.db``) and resolved
against ITS score cache first — any host that ever scored the same
programs against that server makes this sweep free.

    PYTHONPATH=src python examples/compar_sweep_json.py [--backend B]
        [--remote-url http://host:8477] [--remote-token SECRET]
        [--mesh-space]
"""
import argparse
import json
import os
import tempfile

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.combinator import load_sweep_json

SWEEP_SPEC = {
    # which "compilers" to consider, with the flags the user trusts
    # (paper: the user must not pass e.g. no-pointer-aliasing when the
    #  code has aliasing; here: flags are safe by construction)
    "providers": {"tensor_par": ["shard_vocab"], "fsdp": []},
    # OpenMP directive-clause analogue
    "clauses": {"remat": ["none", "dots"], "block_q": [16]},
    # RTL-routine analogue: swept as the outer knob axis
    "globals": {"microbatches": [1, 2]},
}

#: the topology axis (--mesh-space): local vs a 2-way data-parallel
#: mesh, raced as a second outer dimension.  Needs >=2 local devices
#: (CI runs it under XLA_FLAGS=--xla_force_host_platform_device_count=4);
#: the plan's mesh is CHOSEN by the joint argmin, and meshed points
#: score on the process/remote backends like any other job — the specs
#: are JSON, so workers rebuild the mesh themselves.
MESH_SPACE = [None, {"data": 2}]


def main(backend: str = "thread", remote_url: str = None,
         remote_token: str = None, mesh_space: bool = False):
    spec = dict(SWEEP_SPEC)
    if mesh_space:
        spec["meshes"] = MESH_SPACE
    spec_path = os.path.join(tempfile.gettempdir(), "sweep_spec.json")
    with open(spec_path, "w") as f:
        json.dump(spec, f, indent=2)
    print(f"sweep spec written to {spec_path}")

    # the typed sweep input: one SweepSpec value instead of the legacy
    # positional 4-tuple (which still unpacks, with a DeprecationWarning)
    sweep_spec = load_sweep_json(spec_path)
    cfg = get_arch("stablelm-3b").smoke()
    shape = get_shape("train_4k").smoke()

    db_path = os.path.join(tempfile.gettempdir(), "compar_sweep.db")
    if os.path.exists(db_path):
        os.remove(db_path)
    db = SweepDB(db_path)

    workers = 1 if backend == "sequential" else (os.cpu_count() or 1)
    if remote_url:
        print(f"scoring remotely against {remote_url}")
    # first run: New mode, with the sweep-engine knobs on (parallel
    # scoring + exact lower-bound pruning; see docs/sweep_engine.md),
    # the JSON spec's "globals" grid as the outer knob axis, and — with
    # --mesh-space — its "meshes" list as the topology axis
    tuner = ComParTuner(cfg, shape, mesh=None, db=db, project="json-demo",
                        mode="new", executor="dryrun")
    plan, rep = tuner.sweep(spec=sweep_spec, max_flags=1,
                            backend=backend, workers=workers, prune=True,
                            remote_url=remote_url,
                            remote_token=remote_token)
    print("first run:", rep.summary())
    assert rep.n_knob_points == 2
    print("per-knob fused totals:", rep.per_knob_total_s)
    if sweep_spec.meshes is not None:
        assert rep.n_mesh_points == len(MESH_SPACE)
        assert plan.mesh is not None       # the topology was chosen
        print("per-mesh fused totals:", rep.per_mesh_total_s)

    # second run: Continue mode — everything cached, near-instant
    db2 = SweepDB(db_path)
    tuner2 = ComParTuner(cfg, shape, mesh=None, db=db2,
                         project="json-demo", mode="continue",
                         executor="dryrun")
    plan2, rep2 = tuner2.sweep(spec=sweep_spec,
                               max_flags=1, backend=backend,
                               remote_url=remote_url,
                               remote_token=remote_token)
    print("continue run:", rep2.summary())
    assert rep2.elapsed_s < rep.elapsed_s
    assert plan2.knobs == plan.knobs       # the joint argmin is stable
    assert plan2.mesh == plan.mesh
    print("\nfused plan (knobs chosen by the sweep, not supplied):")
    print(plan2.describe())

    # certify + persist the winner: the saved JSON is what you'd ship to
    # a training job, and what the lint CLI re-checks in CI
    #   python -m repro.analysis.lint /tmp/compar_sweep_plan.json
    diags = plan2.lint(cfg, shape)
    assert not diags, f"fused plan failed its own lint: {diags}"
    plan_path = os.path.join(tempfile.gettempdir(), "compar_sweep_plan.json")
    plan2.save(plan_path)
    print(f"fused plan written to {plan_path} (lint: clean)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "sequential", "process", "remote"))
    ap.add_argument("--remote-url", dest="remote_url", default=None,
                    help="sweep scoring server URL (python -m "
                         "repro.core.backends.server); implies "
                         "--backend remote")
    ap.add_argument("--remote-token", dest="remote_token", default=None,
                    help="shared-secret auth token for a --token scoring "
                         "server (sent as Authorization: Bearer)")
    ap.add_argument("--mesh-space", dest="mesh_space", action="store_true",
                    help="also sweep the JSON 'meshes' topology axis "
                         "(local vs data=2; needs >=2 local devices)")
    main(**vars(ap.parse_args()))
