"""ComPar-style JSON-driven sweep (the paper's three-JSON input UX) with
DB Continue mode: run once, kill it, run again — finished combinations
are not re-executed.

    PYTHONPATH=src python examples/compar_sweep_json.py
"""
import json
import os
import tempfile

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.combinator import load_sweep_json

SWEEP_SPEC = {
    # which "compilers" to consider, with the flags the user trusts
    # (paper: the user must not pass e.g. no-pointer-aliasing when the
    #  code has aliasing; here: flags are safe by construction)
    "providers": {"tensor_par": ["shard_vocab"], "fsdp": []},
    # OpenMP directive-clause analogue
    "clauses": {"remat": ["none", "dots"], "block_q": [16]},
    # RTL-routine analogue
    "globals": {"microbatches": [1, 2]},
}


def main():
    spec_path = os.path.join(tempfile.gettempdir(), "sweep_spec.json")
    with open(spec_path, "w") as f:
        json.dump(SWEEP_SPEC, f, indent=2)
    print(f"sweep spec written to {spec_path}")

    providers, clause_space, global_space = load_sweep_json(spec_path)
    cfg = get_arch("stablelm-3b").smoke()
    shape = get_shape("train_4k").smoke()

    db_path = os.path.join(tempfile.gettempdir(), "compar_sweep.db")
    if os.path.exists(db_path):
        os.remove(db_path)
    db = SweepDB(db_path)

    # first run: New mode, with the sweep-engine knobs on (parallel
    # scoring + exact lower-bound pruning; see docs/sweep_engine.md)
    tuner = ComParTuner(cfg, shape, mesh=None, db=db, project="json-demo",
                        mode="new", executor="dryrun")
    plan, rep = tuner.sweep(providers=providers, clause_space=clause_space,
                            max_flags=1, workers=os.cpu_count() or 1,
                            prune=True)
    print("first run:", rep.summary())

    # second run: Continue mode — everything cached, near-instant
    db2 = SweepDB(db_path)
    tuner2 = ComParTuner(cfg, shape, mesh=None, db=db2,
                         project="json-demo", mode="continue",
                         executor="dryrun")
    plan2, rep2 = tuner2.sweep(providers=providers,
                               clause_space=clause_space, max_flags=1)
    print("continue run:", rep2.summary())
    assert rep2.elapsed_s < rep.elapsed_s
    print("\nfused plan:")
    print(plan2.describe())


if __name__ == "__main__":
    main()
