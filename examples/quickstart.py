"""Quickstart: tune a model with ComParX and train with the fused plan.

Runs in ~2 minutes on CPU (reduced config).  The same API drives the
production dry-run on the 256/512-chip meshes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner
from repro.core.plan import uniform_plan
from repro.models.context import SegmentClause
from repro.train.step import init_train_state, jit_train_step
from repro.data.pipeline import SyntheticLM


def main():
    # 1) pick an architecture + shape (reduced for CPU)
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    print(f"arch={cfg.name}  d_model={cfg.d_model}  layers={cfg.num_layers}")

    # 2) ComPar sweep: enumerate (provider x flags x clauses) per segment,
    #    time each empirically, fuse the per-segment winners
    tuner = ComParTuner(cfg, shape, mesh=None, executor="wallclock",
                        project="quickstart", timeout_s=120)
    space = {"remat": ("none", "dots"), "kernel": ("xla",),
             "block_q": (16,), "block_k": (16,), "scan_unroll": (1,),
             "mlstm_chunk": (16,)}
    plan, report = tuner.sweep(providers=["tensor_par", "fsdp"],
                               clause_space=space, max_flags=1)
    print("\n--- sweep report ---")
    print(report.summary())
    print("\n--- fused plan (the ComPar output) ---")
    print(plan.describe())
    print("\nuniform baselines (predicted step seconds):")
    for prov, total in tuner.baselines().items():
        print(f"  {prov:12s} {total:.4f}s")
    print(f"  {'FUSED':12s} {plan.meta['predicted_total_s']:.4f}s")

    # 3) train a few steps with the fused plan
    step, _ = jit_train_step(cfg, None, plan)
    params, opt = init_train_state(cfg, plan, jax.random.key(0))
    data = SyntheticLM(cfg, shape, seed=0)
    print("\n--- training with the fused plan ---")
    for s in range(10):
        params, opt, metrics = step(params, opt, data.batch_at(s))
        if s % 3 == 0 or s == 9:
            print(f"step {s}: loss={float(metrics['total_loss']):.4f}")
    plan.save("/tmp/quickstart_plan.json")
    print("\nplan saved to /tmp/quickstart_plan.json")


if __name__ == "__main__":
    main()
