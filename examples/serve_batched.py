"""Serve a small model with batched requests: prefill-by-decode warmup,
then batched greedy generation with a KV cache / recurrent state under a
ComParX serving plan.  Compares two archs (dense KV-cache vs recurrent
O(1)-state) on the same harness.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_shape
from repro.launch.dryrun import default_plan
from repro.models.model import init_cache, model_specs
from repro.models.params import init_params
from repro.serve.step import make_decode_step


def generate(arch: str, batch: int = 4, prompt_len: int = 8,
             gen_len: int = 24, cache_len: int = 64):
    cfg = get_arch(arch).smoke()
    shape = get_shape("decode_32k").smoke()
    plan = default_plan(cfg, shape)
    params = init_params(model_specs(cfg), jax.random.key(0))
    step, _ = make_decode_step(cfg, None, plan)
    step = jax.jit(step, donate_argnums=(1,))

    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len),
                                 0, cfg.vocab_size)
    caches = init_cache(cfg, batch, cache_len)

    # prefill by decoding the prompt (cache fills token by token)
    tok = prompts[:, 0]
    for pos in range(prompt_len):
        nxt, _, caches = step(params, caches, prompts[:, pos],
                              jnp.int32(pos))
    # batched greedy generation
    out = []
    t0 = time.perf_counter()
    tok = nxt
    for pos in range(prompt_len, prompt_len + gen_len):
        tok, _, caches = step(params, caches, tok, jnp.int32(pos))
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seqs = jnp.stack(out, axis=1)
    print(f"[{arch}] {batch} seqs x {gen_len} tokens "
          f"in {dt:.2f}s ({batch * gen_len / dt:.1f} tok/s)  "
          f"sample={seqs[0][:10].tolist()}")
    return seqs


def main():
    print("dense KV-cache arch:")
    generate("granite-8b")
    print("recurrent O(1)-state arch (no KV growth):")
    generate("recurrentgemma-2b")


if __name__ == "__main__":
    main()
