"""End-to-end driver: train a ~100M-parameter xLSTM for a few hundred
steps on the synthetic-language pipeline, with async checkpoints and a
mid-run simulated crash + restart (fault-tolerance demo).

This is the paper-kind-appropriate e2e example ("train ~100M model for a
few hundred steps").  On this CPU container the default is a narrower
model + fewer steps so it finishes in minutes; pass --full for the real
xlstm-125m (slow on 1 CPU core, unchanged code path).

    PYTHONPATH=src python examples/train_e2e.py [--full] [--steps N]
"""
import argparse
import dataclasses
import shutil

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="true xlstm-125m @ 100M params (slow on CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt, ignore_errors=True)
    base = ["--arch", "xlstm-125m", "--ckpt-dir", args.ckpt,
            "--ckpt-every", "25", "--log-every", "10",
            "--batch", "8", "--seq", "64"]
    if not args.full:
        base.insert(2, "--smoke")

    half = args.steps // 2
    print(f"=== phase 1: steps 0..{half} (then simulated crash) ===")
    train(base + ["--steps", str(half)])

    print(f"\n=== phase 2: restart from latest checkpoint, steps "
          f"{half}..{args.steps} ===")
    losses = train(base + ["--steps", str(args.steps)])
    print(f"\nfinal loss after restart-resume: {losses[-1]:.4f}")
    print("fault-tolerance contract held: data + RNG replayed exactly "
          "from the checkpoint step.")


if __name__ == "__main__":
    main()
