"""Static validity analysis for sweep points and fused plans (PlanLint).

ComPar's promise is "the best parallel code possible while maintaining
the program's validity" — but until now every validity mechanism was
dynamic: the black-box numerics check pays a real forward pass, and a
divisibility mistake (microbatch split, pallas tile, mesh axis) pays a
full compile — or a spawned worker — to discover the point was never
viable.  This package lints sweep points *without compiling anything*:

* :func:`analyze_point` — rule-based diagnostics for one
  (combination, knobs, mesh) sweep point against one or more segments.
* :func:`analyze_plan` — certify a fused plan post-fusion (per-segment
  point lint + cross-segment boundary coherence).
* :func:`lint_schedule` — the kernel-schedule subset, shared with the
  kernel autotuner (``kernels/autotune.py``) so statically-broken tile
  variants are rejected before their isolated compile.

Soundness contract: every ``error``-severity diagnostic marks a point
that *provably* fails when compiled (or an unsatisfiable mesh) — that is
what lets ``sweep(static_checks="strict")`` drop them without changing
any fused plan.  Anything merely suspicious (silent chunk clamping,
sharding fallback to replication, low-precision accumulation) is a
``warn`` and never drops a point.

CLI: ``python -m repro.analysis.lint <plan.json|sweep.json>``.
"""
from repro.analysis.diagnostics import Diagnostic, errors, format_diagnostics
from repro.analysis.planlint import analyze_plan
from repro.analysis.rules import analyze_point, lint_schedule

__all__ = [
    "Diagnostic",
    "analyze_plan",
    "analyze_point",
    "errors",
    "format_diagnostics",
    "lint_schedule",
]
