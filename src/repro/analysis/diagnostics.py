"""Structured diagnostics — the output format of every lint rule.

A :class:`Diagnostic` is one finding: a stable rule id (the histogram
key in ``SweepReport.static_rules``), a severity, a human message, the
segment it applies to (``""`` = the whole point), and machine-readable
evidence (the numbers the rule compared).  Severity semantics:

* ``error`` — the point provably fails when compiled (or the mesh is
  unsatisfiable on this host).  ``static_checks="strict"`` drops these
  before they become JobSpecs; the soundness test force-compiles every
  dropped point and asserts the failure is real.
* ``warn``  — suspicious but viable (silent clamping, replication
  fallback, precision hazards).  Never drops a point.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

ERROR = "error"
WARN = "warn"


@dataclass
class Diagnostic:
    rule: str                      # stable rule id, e.g. "attn-tile"
    severity: str                  # "error" | "warn"
    message: str
    segment: str = ""              # "" = applies to the whole point
    evidence: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self):
        if self.severity not in (ERROR, WARN):
            raise ValueError(f"severity {self.severity!r}")

    @property
    def is_error(self) -> bool:
        return self.severity == ERROR

    def to_json(self) -> Dict:
        return {"rule": self.rule, "severity": self.severity,
                "message": self.message, "segment": self.segment,
                "evidence": dict(self.evidence)}

    @classmethod
    def from_json(cls, d: Dict) -> "Diagnostic":
        return cls(d["rule"], d["severity"], d["message"],
                   d.get("segment", ""), dict(d.get("evidence") or {}))

    def __str__(self) -> str:
        where = f" [{self.segment}]" if self.segment else ""
        return f"{self.severity.upper()} {self.rule}{where}: {self.message}"


def errors(diags: Sequence[Diagnostic]) -> List[Diagnostic]:
    """The error-severity subset (what strict mode acts on)."""
    return [d for d in diags if d.is_error]


def format_diagnostics(diags: Sequence[Diagnostic]) -> str:
    """One line per diagnostic, errors first (stable within severity)."""
    ordered = sorted(diags, key=lambda d: (d.severity != ERROR,))
    return "\n".join(str(d) for d in ordered)
