"""PlanLint CLI: ``python -m repro.analysis.lint <plan.json|sweep.json>``.

The input kind is detected from the JSON shape:

* a **plan** (``Plan.save`` output — has a top-level ``"segments"``
  object) is certified with :func:`repro.analysis.analyze_plan`;
* a **sweep spec** (the ComPar-style JSON the examples feed
  ``load_sweep_json`` — ``providers``/``clauses``/``globals``/
  ``meshes``) has every enumerated (combination, knob, mesh) point
  linted with :func:`repro.analysis.analyze_point`.

Exit status: 0 = clean or warnings only, 1 = usage/IO error,
2 = error-severity diagnostics found (the CI-gate signal; warnings
also exit 2 under ``--strict``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.analysis.diagnostics import Diagnostic


def _load(path: str):
    with open(path) as f:
        return json.load(f)


def _lint_plan(cfg, shape, doc, trace: bool) -> List[Diagnostic]:
    from repro.analysis.planlint import analyze_plan
    from repro.core.plan import Plan
    return analyze_plan(cfg, shape, Plan.from_json(doc), trace=trace)


def _lint_sweep(cfg, shape, path: str, trace: bool) -> List[Diagnostic]:
    from repro.analysis.rules import analyze_point
    from repro.core.combinator import (enumerate_combinations, global_grid,
                                       load_sweep_json)
    spec = load_sweep_json(path)
    combos = enumerate_combinations(list(spec.providers), spec.clauses)
    points = global_grid(spec.globals)
    mpoints = list(spec.meshes) if spec.meshes is not None else [None]
    out: List[Diagnostic] = []
    n_points = 0
    for mp in mpoints:
        for kn in points:
            for c in combos:
                n_points += 1
                for d in analyze_point(cfg, shape, c, knobs=kn, mesh=mp,
                                       trace=trace):
                    d.evidence.setdefault("combination", c.label())
                    d.evidence.setdefault("knobs", kn.key())
                    if mp is not None:
                        d.evidence.setdefault("mesh", mp.key())
                    out.append(d)
    print(f"linted {n_points} sweep point(s) "
          f"({len(combos)} combination(s) x {len(points)} knob point(s) "
          f"x {len(mpoints)} mesh point(s))")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Static validity lint for sweep specs and fused plans")
    ap.add_argument("path", help="plan JSON (Plan.save) or sweep-spec JSON")
    ap.add_argument("--arch", default="stablelm-3b",
                    help="architecture id (default: stablelm-3b)")
    ap.add_argument("--shape", default="train_4k",
                    help="shape id (default: train_4k)")
    ap.add_argument("--full", action="store_true",
                    help="lint at full scale (default: the smoke "
                    "derivation, matching the examples/CI)")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the abstract-trace rules (donation/trace)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 2 on warnings too, not just errors")
    args = ap.parse_args(argv)

    from repro.configs.registry import get_arch, get_shape
    cfg, shape = get_arch(args.arch), get_shape(args.shape)
    if not args.full:
        cfg, shape = cfg.smoke(), shape.smoke()

    try:
        doc = _load(args.path)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {args.path}: {e}", file=sys.stderr)
        return 1
    trace = not args.no_trace
    if isinstance(doc, dict) and isinstance(doc.get("segments"), dict):
        diags = _lint_plan(cfg, shape, doc, trace)
        kind = "plan"
    else:
        diags = _lint_sweep(cfg, shape, args.path, trace)
        kind = "sweep spec"

    for d in diags:
        print(str(d))
    n_err = sum(1 for d in diags if d.is_error)
    n_warn = len(diags) - n_err
    print(f"{kind} {args.path}: {n_err} error(s), {n_warn} warning(s) "
          f"[arch={cfg.name} shape={shape.name}]")
    if n_err or (args.strict and n_warn):
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
