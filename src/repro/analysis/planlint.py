"""Plan-level lint: certify a fused plan post-fusion.

Per-segment, a fused plan is just a set of sweep points, so every
point-level rule applies (``analyze_point`` with the plan's knobs and
chosen mesh).  The genuinely cross-segment rule lives here:

``boundary-reshard``  adjacent segments whose resolved residual-stream
                  partitions differ force a resharding at the segment
                  boundary.  ``fuse`` in per-segment-argmin mode never
                  priced that transfer (``boundary_costs=False``), so
                  the plan's predicted total silently omits a real
                  collective — a Viterbi-fused plan
                  (``meta["fusion"] == "viterbi-boundary"``) priced it
                  and is exempt.                                  [warn]
``missing-segment``  the plan carries no combination for a segment of
                  this config; ``build_contexts`` will substitute
                  another segment's combination (loudly).         [warn]
"""
from __future__ import annotations

from typing import List

from repro.analysis.diagnostics import ERROR, WARN, Diagnostic
from repro.analysis.rules import analyze_point, residual_pspec
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.plan import Plan
from repro.core.segment import fragment


def analyze_plan(cfg: ArchConfig, shape: ShapeConfig, plan: Plan, *,
                 trace: bool = True,
                 check_devices: bool = False) -> List[Diagnostic]:
    """Certify a fused plan: point-level lint of every segment's
    combination under the plan's knobs/mesh, plus the cross-segment
    boundary-coherence rule.  ``trace`` (default on — a plan has few
    segments) enables the abstract-trace rules."""
    segs = fragment(cfg)
    diags: List[Diagnostic] = []
    for seg in segs:
        combo = plan.segments.get(seg.name)
        if combo is None:
            diags.append(Diagnostic(
                "missing-segment", WARN,
                f"plan has no combination for segment {seg.name!r}: "
                f"build_contexts will substitute one",
                segment=seg.name))
            continue
        diags += analyze_point(cfg, shape, combo, knobs=plan.knobs,
                               mesh=plan.mesh, segments=(seg,),
                               check_devices=check_devices, trace=trace)
    diags += _rule_boundaries(cfg, shape, plan)
    diags.sort(key=lambda d: (d.severity != ERROR,))
    return diags


def _rule_boundaries(cfg: ArchConfig, shape: ShapeConfig,
                     plan: Plan) -> List[Diagnostic]:
    mesh = plan.mesh
    if mesh is None or mesh.is_local:
        return []                    # meshless: every partition is trivial
    if plan.meta.get("fusion") == "viterbi-boundary":
        return []                    # boundary costs were priced in
    axis_sizes = mesh.axis_sizes()
    chain = [(s, plan.segments[s.name]) for s in fragment(cfg)
             if s.name in plan.segments]
    out: List[Diagnostic] = []
    for (sa, ca), (sb, cb) in zip(chain, chain[1:]):
        pa = residual_pspec(cfg, shape, ca, sa, axis_sizes)
        pb = residual_pspec(cfg, shape, cb, sb, axis_sizes)
        if pa != pb:
            out.append(Diagnostic(
                "boundary-reshard", WARN,
                f"residual stream resharded at {sa.name}->{sb.name}: "
                f"{pa} vs {pb}, unpriced under per-segment-argmin "
                f"fusion (sweep with boundary_costs=True to price it)",
                segment=sb.name,
                evidence={"from": sa.name, "to": sb.name,
                          "pspec_from": repr(pa), "pspec_to": repr(pb)}))
    return out
