"""Point-level lint rules: one sweep point, zero compiles.

Every rule mirrors a *verified* failure (or degradation) site of this
codebase — the rule ids below name the site they model:

``microbatch``    ``timer._with_microbatches`` raises when the global
                  batch is not divisible by ``knobs.microbatches`` —
                  and training wraps EVERY segment in it.        [error]
``attn-tile``     ``flash_attention_fwd`` asserts ``Sq % block_q == 0
                  and Sk % block_k == 0`` after clamping blocks to the
                  sequence length.                               [error]
``decode-tile``   ``flash_decode_fwd`` asserts ``Smax % block_k == 0``
                  after clamping.  Only an error on the path that
                  provably reaches the kernel (full-causal decode,
                  ``decode_shardmap=False``); the shardmap gate is
                  data-dependent, so under it this demotes to a warn.
                                                          [error|warn]
``mesh-devices``  ``MeshSpec.check_local`` raises MeshUnsatisfiable
                  when this host lacks the devices.  Gated by
                  ``check_devices`` — only local backends know the
                  scoring host's device count.                   [error]
``trace``         the abstract trace (``jax.eval_shape`` — the same
                  tracing ``jit.lower`` performs, no compile) raised;
                  the real compile deterministically raises too.
                  Gated by ``trace=True``.                       [error]
``chunk-clamp``   mLSTM/RG-LRU chunk lengths are silently walked down
                  to a divisor of the sequence (``_clamp_chunk``) — the
                  swept value is not the executed value.          [warn]
``attn-chunk-fallback``  ``chunked_attention`` silently falls back to
                  naive full-matrix attention when the q-chunk does not
                  divide the sequence.                            [warn]
``shard-fallback``  ``Rules._resolve_one`` silently replicates a dim
                  whose mapped mesh axes fail divisibility.       [warn]
``donate-unshaped``  a donated buffer whose shape/dtype matches no
                  output cannot be reused in-place (XLA warns and
                  copies).  Gated by ``trace=True``.              [warn]
``dtype-flow``    low-precision accumulation hazards: bf16 optimizer
                  state under ``opt_state_dtype``, bf16 KV-cache reads
                  with ``cache_upcast=False``.                    [warn]
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.analysis.diagnostics import ERROR, WARN, Diagnostic
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import Combination, GlobalKnobs
from repro.core.meshspec import MeshSpec, MeshUnsatisfiable
from repro.core.segment import Segment, fragment


def _logical_dims(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, int]:
    """Canonical tensor dim per logical axis name — what the provider
    mappings are resolved against when no concrete tensor is at hand."""
    return {
        "batch": shape.global_batch,
        "seq": shape.seq_len,
        "kv_seq": shape.seq_len,
        "embed": cfg.d_model,
        "vocab": cfg.vocab_size,
        "heads": cfg.num_heads,
        "kv_heads": cfg.num_kv_heads,
        "ffn": cfg.d_ff,
        "expert_ffn": cfg.d_ff,
        "experts": cfg.num_experts,
        "rnn": int(cfg.expand_factor * cfg.d_model),
    }


def _axis_sizes(mesh) -> Dict[str, int]:
    """Normalize a mesh argument (MeshSpec | live jax.Mesh | None) to
    ``{axis name: size}`` — the only mesh content any rule needs."""
    if mesh is None:
        return {}
    if isinstance(mesh, MeshSpec):
        return mesh.axis_sizes()
    return dict(zip(mesh.axis_names, (int(d) for d in mesh.devices.shape)))


def resolver(mapping: Dict[str, object], axis_sizes: Dict[str, int]):
    """A ``Rules`` instance resolving against declarative axis sizes —
    no live mesh needed.  ``Rules.pspec``/``_resolve_one`` only consult
    ``mapping`` and ``axis_sizes``, so this IS the production resolution
    (not a reimplementation that could drift)."""
    from repro.runtime.sharding import Rules, _as_candidates
    r = Rules.__new__(Rules)
    r.mapping = {k: _as_candidates(v) for k, v in (mapping or {}).items()}
    r.mesh = None
    r.axis_sizes = dict(axis_sizes)
    return r


def residual_pspec(cfg: ArchConfig, shape: ShapeConfig, combo: Combination,
                   seg: Segment, axis_sizes: Dict[str, int]):
    """The resolved partition of the residual stream entering/leaving
    ``seg`` under ``combo`` — the cross-segment boundary contract."""
    from repro.core.providers import get_provider
    mapping = get_provider(combo.provider).mapping(
        cfg, axis_sizes, combo.flags, seg)
    r = resolver(mapping, axis_sizes)
    if shape.kind == "decode":
        axes = ("batch", "embed")
        dims = (shape.global_batch, cfg.d_model)
    else:
        axes = ("batch", "seq", "embed")
        dims = (shape.global_batch, shape.seq_len, cfg.d_model)
    return tuple(r.pspec(axes, dims))


def _clamp_chunk(chunk: int, S: int) -> int:
    c = min(int(chunk), S)
    while S % c:
        c -= 1
    return c


# --- kernel-schedule subset (shared with kernels/autotune.py) ---------------

def lint_schedule(op: str, fields: Dict[str, object], cfg: ArchConfig,
                  shape: ShapeConfig) -> List[Diagnostic]:
    """Lint one (op, schedule) variant of the kernel autotuner's grid.

    The isolated op programs (``autotune._op_program``) call the kernels
    directly, so the tile-divisibility asserts fire unconditionally —
    errors here are sound for the autotuner's pre-compile rejection."""
    out: List[Diagnostic] = []
    S = shape.seq_len
    kernel = fields.get("kernel", "xla")
    if op == "flash_attention":
        if kernel == "pallas":
            for f in ("block_q", "block_k"):
                b = min(int(fields[f]), S)
                if S % b:
                    out.append(Diagnostic(
                        "attn-tile", ERROR,
                        f"seq_len {S} not divisible by {f}={fields[f]} "
                        f"(clamped to {b}): flash_attention asserts",
                        evidence={"seq_len": S, f: int(fields[f]),
                                  "clamped": b}))
        else:
            bq = int(fields.get("block_q", 512))
            if S > bq and S % bq:
                out.append(Diagnostic(
                    "attn-chunk-fallback", WARN,
                    f"q_chunk {bq} does not divide seq_len {S}: "
                    f"chunked_attention silently falls back to naive "
                    f"full-matrix attention",
                    evidence={"seq_len": S, "block_q": bq}))
    elif op == "flash_decode" and kernel == "pallas":
        bk = min(int(fields["block_k"]), S)
        if S % bk:
            out.append(Diagnostic(
                "decode-tile", ERROR,
                f"cache length {S} not divisible by block_k="
                f"{fields['block_k']} (clamped to {bk}): flash_decode "
                f"asserts",
                evidence={"cache_len": S, "block_k": int(fields["block_k"]),
                          "clamped": bk}))
    elif op in ("mlstm_chunkwise", "rglru") and kernel == "pallas":
        c = int(fields.get("mlstm_chunk", 256))
        eff = _clamp_chunk(c, S)
        if eff != min(c, S):
            out.append(Diagnostic(
                "chunk-clamp", WARN,
                f"mlstm_chunk {c} silently clamped to {eff} "
                f"(largest divisor of seq_len {S})",
                evidence={"seq_len": S, "mlstm_chunk": c, "effective": eff}))
    return out


# --- per-point rules --------------------------------------------------------

def _rule_microbatch(shape, knobs) -> List[Diagnostic]:
    if shape.kind != "train" or knobs is None:
        return []
    mb = knobs.microbatches
    if mb > 1 and shape.global_batch % mb:
        # _with_microbatches wraps every train segment program, so the
        # point fails on all of them — one global diagnostic
        return [Diagnostic(
            "microbatch", ERROR,
            f"global_batch {shape.global_batch} not divisible by "
            f"microbatches={mb}: the gradient-accumulation split raises",
            evidence={"global_batch": shape.global_batch,
                      "microbatches": mb})]
    return []


def _rule_tiles(cfg, shape, combo, seg) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    if seg.kind != "stack":
        return out
    cl = combo.clause
    S = shape.seq_len
    if seg.has_attn and shape.kind in ("train", "prefill"):
        for d in lint_schedule(
                "flash_attention",
                {"kernel": cl.kernel, "block_q": cl.block_q,
                 "block_k": cl.block_k}, cfg, shape):
            d.segment = seg.name
            out.append(d)
    if seg.has_attn and shape.kind == "decode" and not cfg.window_size:
        if cl.kernel == "pallas":
            for d in lint_schedule(
                    "flash_decode",
                    {"kernel": cl.kernel, "block_k": cl.block_k},
                    cfg, shape):
                d.segment = seg.name
                if cl.decode_shardmap:
                    # the shardmap gate (attn_decode) is data-dependent
                    # (needs the cache's seq dim actually sharded), so
                    # the kernel is only *maybe* reached — not provable
                    d.severity = WARN
                    d.message += (" (decode_shardmap=True may bypass "
                                  "the kernel; not provably fatal)")
                out.append(d)
    if seg.has_recurrent and shape.kind in ("train", "prefill") \
            and cl.kernel == "pallas":
        for d in lint_schedule(
                "mlstm_chunkwise",
                {"kernel": cl.kernel, "mlstm_chunk": cl.mlstm_chunk},
                cfg, shape):
            d.segment = seg.name
            out.append(d)
    return out


def _rule_mesh_devices(mesh) -> List[Diagnostic]:
    if not isinstance(mesh, MeshSpec) or mesh.is_local:
        return []
    try:
        mesh.check_local()
    except MeshUnsatisfiable as e:
        return [Diagnostic(
            "mesh-devices", ERROR, str(e),
            evidence={"mesh": mesh.key(), "needs": mesh.n_devices})]
    return []


def _rule_shard_fallback(cfg, shape, combo, seg,
                         axis_sizes) -> List[Diagnostic]:
    if not axis_sizes:
        return []
    from repro.core.providers import get_provider
    mapping = get_provider(combo.provider).mapping(
        cfg, axis_sizes, combo.flags, seg)
    r = resolver(mapping, axis_sizes)
    dims = _logical_dims(cfg, shape)
    out: List[Diagnostic] = []
    for name, cands in sorted(r.mapping.items()):
        if cands[0] is None or name not in dims:
            continue
        # only a *divisibility* fallback is news: a candidate whose mesh
        # axes simply don't exist here is structural (provider mappings
        # are mesh-generic), not a silently-degraded sharding
        reachable = []
        for cand in cands:
            if cand is None:
                continue
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            axes = tuple(a for a in axes if a in axis_sizes)
            if axes:
                reachable.append(axes)
        if not reachable:
            continue
        dim = dims[name]
        if r._resolve_one(name, dim, set()) is None:
            out.append(Diagnostic(
                "shard-fallback", WARN,
                f"logical axis {name!r} (dim {dim}) not divisible by "
                f"its mapped mesh axes {reachable[0]!r} under mesh "
                f"{axis_sizes}: silently replicated",
                segment=seg.name,
                evidence={"axis": name, "dim": dim,
                          "mesh": dict(axis_sizes)}))
    return out


def _rule_opt_dtype(shape, knobs) -> List[Diagnostic]:
    if shape.kind == "train" and knobs is not None \
            and knobs.opt_state_dtype == "bfloat16":
        return [Diagnostic(
            "dtype-flow", WARN,
            "opt_state_dtype=bfloat16: optimizer-state accumulation in "
            "bf16 loses small updates (~8 bits of mantissa)",
            evidence={"opt_state_dtype": knobs.opt_state_dtype})]
    return []


def _rule_dtype_flow(cfg, shape, combo, seg) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    if shape.kind == "decode" and seg.kind == "stack" and seg.has_attn \
            and not combo.clause.cache_upcast and cfg.dtype == "bfloat16":
        out.append(Diagnostic(
            "dtype-flow", WARN,
            "cache_upcast=False with a bfloat16 KV cache: attention "
            "logits accumulate in reduced precision",
            segment=seg.name,
            evidence={"dtype": cfg.dtype,
                      "cache_upcast": combo.clause.cache_upcast}))
    return out


def _rule_donation(cfg, shape, combo, knobs, seg) -> List[Diagnostic]:
    """Abstract-trace the segment program (``jax.eval_shape`` — exactly
    the tracing ``jit.lower`` performs, but no compile) and flag donated
    buffers whose shape/dtype matches no output.  A failing trace is
    itself a sound *error*: the compile traces identically."""
    if shape.kind != "train" or knobs is None:
        return []
    import jax
    from repro.core.timer import segment_program
    try:
        fn, args, _ = segment_program(cfg, shape, seg, combo, None,
                                      knobs=knobs)
        out_shapes = jax.eval_shape(fn, *args)
    except Exception as e:
        return [Diagnostic(
            "trace", ERROR,
            f"abstract trace failed: {type(e).__name__}: {e}",
            segment=seg.name,
            evidence={"exception": type(e).__name__})]
    if not knobs.donate:
        return []
    # DryRunExecutor donates argnums (0,) — the segment params — on
    # train shapes; a donated leaf is reusable iff some output leaf has
    # its exact shape+dtype (XLA aliasing granularity)
    avail: Dict[tuple, int] = {}
    for leaf in jax.tree.leaves(out_shapes):
        key = (tuple(leaf.shape), str(leaf.dtype))
        avail[key] = avail.get(key, 0) + 1
    unmatched = 0
    for leaf in jax.tree.leaves(args[0]):
        key = (tuple(leaf.shape), str(leaf.dtype))
        if avail.get(key, 0) > 0:
            avail[key] -= 1
        else:
            unmatched += 1
    if unmatched:
        return [Diagnostic(
            "donate-unshaped", WARN,
            f"{unmatched} donated param buffer(s) match no output "
            f"shape/dtype: donation cannot alias them (XLA copies)",
            segment=seg.name, evidence={"unmatched": unmatched})]
    return []


# --- entry point ------------------------------------------------------------

def analyze_point(cfg: ArchConfig, shape: ShapeConfig, combo: Combination,
                  knobs: Optional[GlobalKnobs] = None, mesh=None,
                  segments: Optional[Sequence[Segment]] = None, *,
                  check_devices: bool = False,
                  trace: bool = False) -> List[Diagnostic]:
    """Lint one sweep point without compiling anything.

    ``mesh`` accepts a :class:`MeshSpec` (a swept topology point), a
    live ``jax.Mesh`` (a fixed constructor mesh), or ``None``;
    ``segments`` defaults to every segment of ``cfg`` (pass one to lint
    a single scheduler row).  ``check_devices`` enables the host-local
    mesh satisfiability check (only meaningful where the linting host is
    the scoring host); ``trace`` enables the abstract-trace rules
    (donation safety + trace failures) — cheap per point but not free,
    so the scheduler's bulk path leaves it off and the plan lint turns
    it on.

    Returns structured :class:`Diagnostic` records, errors first.
    """
    segs = list(segments) if segments is not None else list(fragment(cfg))
    axis_sizes = _axis_sizes(mesh)
    diags: List[Diagnostic] = []
    diags += _rule_microbatch(shape, knobs)
    diags += _rule_opt_dtype(shape, knobs)
    if check_devices:
        diags += _rule_mesh_devices(mesh)
    for seg in segs:
        diags += _rule_tiles(cfg, shape, combo, seg)
        diags += _rule_shard_fallback(cfg, shape, combo, seg, axis_sizes)
        diags += _rule_dtype_flow(cfg, shape, combo, seg)
        if trace:
            diags += _rule_donation(cfg, shape, combo, knobs, seg)
    diags.sort(key=lambda d: (d.severity != ERROR,))
    return diags
