"""Checkpointing: atomic, async, mesh-elastic, keep-N garbage-collected.

Design for 1000+ nodes:

* **Atomic commit** — write to ``step_XXXX.tmp/``, fsync, rename, then
  write a ``manifest.json`` last; a checkpoint without a manifest is
  ignored on restore, so a mid-write crash can never corrupt restart.
* **Mesh-elastic** — tensors are saved *unsharded by logical identity*
  (gathered per leaf) with the param-spec tree; restore re-shards onto
  whatever mesh/plan the restarting job uses (elastic scaling: restart on
  a different pod count re-shards transparently).  On a real pod this
  becomes per-shard writes + a distributed manifest; the commit protocol
  and layout are identical.
* **Async** — ``save_async`` snapshots device arrays to host then writes
  in a background thread, overlapping I/O with the next training steps.
* **Keep-N GC** + step-indexed data/RNG state so restart replays exactly.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(tree_like, flat: Dict[str, np.ndarray]):
    def rebuild(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        return arr
    return jax.tree_util.tree_map_with_path(rebuild, tree_like)


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict] = None):
        """Synchronous atomic save. ``state``: name -> pytree."""
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {}
        for name, tree in state.items():
            flat = _flatten(tree)
            np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
            index[name] = sorted(flat)
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        os.replace(tmp, final)          # atomic on POSIX
        manifest = {"step": step, "time": time.time(),
                    "extra": extra or {}, "names": sorted(state)}
        mpath = os.path.join(final, MANIFEST)
        with open(mpath + ".tmp", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mpath + ".tmp", mpath)
        self._gc()
        return final

    def save_async(self, step: int, state: Dict[str, Any],
                   extra: Optional[Dict] = None):
        """Snapshot to host memory now, write in the background."""
        host_state = {name: jax.tree.map(lambda x: np.asarray(x), tree)
                      for name, tree in state.items()}
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(step, host_state, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, MANIFEST)):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Dict[str, Any],
                step: Optional[int] = None,
                shardings: Optional[Dict[str, Any]] = None
                ) -> Tuple[int, Dict[str, Any], Dict]:
        """Restore state trees; re-shard onto ``shardings`` if given
        (elastic restore onto any mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        out = {}
        for name, tree in tree_like.items():
            with np.load(os.path.join(d, f"{name}.npz")) as z:
                flat = {k: z[k] for k in z.files}
            restored = _unflatten(tree, flat)
            if shardings is not None and name in shardings:
                restored = jax.tree.map(
                    lambda arr, sh: jax.device_put(arr, sh),
                    restored, shardings[name])
            out[name] = restored
        return step, out, manifest.get("extra", {})

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
