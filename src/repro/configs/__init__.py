from repro.configs.base import (  # noqa: F401
    ArchConfig, ShapeConfig, ScanGroup, SHAPES,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, shape_applies,
)
from repro.configs.registry import ARCHS, get_arch, get_shape, all_cells  # noqa: F401
