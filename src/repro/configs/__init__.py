from repro.configs.base import (  # noqa: F401
    ArchConfig, ShapeConfig, ScanGroup, SHAPES,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, shape_applies,
)
from repro.configs.registry import (  # noqa: F401
    ARCHS, all_cells, arch_from_spec, arch_to_spec, get_arch, get_shape,
    shape_from_spec, shape_to_spec,
)
