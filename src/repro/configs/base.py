"""Architecture / shape configuration dataclasses.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The
config is purely declarative — model construction (``repro.models``) and
the ComPar tuner (``repro.core``) both consume it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ScanGroup:
    """A homogeneous, scannable group of blocks.

    ``pattern`` is the tuple of block kinds inside one super-block;
    ``repeats`` is how many times the super-block repeats (the scan
    length).  ``repeats == 1`` with a short pattern is simply unrolled.
    Block kinds: ``attn`` (attention + dense FFN), ``attn_moe``
    (attention + MoE FFN), ``rec`` (RG-LRU recurrent block + FFN),
    ``mlstm`` / ``slstm`` (xLSTM blocks, no separate FFN).
    """

    pattern: Tuple[str, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense FFN hidden size (per-expert size for MoE)
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    first_k_dense: int = 0         # leading dense layers in an MoE stack
    moe_capacity_factor: float = 1.25
    # --- stack pattern (repeats to cover num_layers) ---
    block_pattern: Tuple[str, ...] = ("attn",)
    # --- attention details ---
    window_size: int = 0           # 0 = full causal; >0 = sliding window
    rope: str = "full"             # full | 2d | none
    # --- xLSTM / recurrent details ---
    expand_factor: float = 2.0     # internal expansion of mlstm/rec blocks
    conv_width: int = 4            # temporal conv width in rec/mlstm blocks
    # --- misc ---
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    glu: bool = True               # gated FFN (SwiGLU/GeGLU) vs plain MLP
    frontend: str = "none"         # none | patch | frame   (vlm/audio stubs)
    tie_embeddings: bool = False
    sub_quadratic: bool = False    # may run the long_500k shape
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def stack_plan(self) -> Tuple[ScanGroup, ...]:
        """Split the layer stack into scannable homogeneous groups."""
        groups = []
        n = self.num_layers
        pat = tuple(self.block_pattern)
        if self.is_moe:
            # first_k_dense leading dense layers, the rest MoE.
            if self.first_k_dense:
                groups.append(ScanGroup(("attn",) * self.first_k_dense, 1))
                n -= self.first_k_dense
            groups.append(ScanGroup(("attn_moe",), n))
            return tuple(groups)
        reps, rem = divmod(n, len(pat))
        if reps:
            groups.append(ScanGroup(pat, reps))
        if rem:
            groups.append(ScanGroup(pat[:rem], 1))
        return tuple(groups)

    def block_kinds(self) -> Tuple[str, ...]:
        """The flattened sequence of block kinds, length num_layers."""
        out = []
        for g in self.stack_plan():
            out.extend(g.pattern * g.repeats)
        assert len(out) == self.num_layers, (self.name, len(out), self.num_layers)
        return tuple(out)

    def smoke(self) -> "ArchConfig":
        """A tiny config of the same *family* for CPU smoke tests."""
        pat = tuple(self.block_pattern)
        num_layers = max(len(pat), 2) if not self.is_moe else 2 + self.first_k_dense
        kv = min(self.num_kv_heads, 2)
        heads = max(4 // max(1, 4 // max(self.q_per_kv * kv, 1)), kv)
        # keep the q/kv ratio >= 1 and divisibility
        heads = kv * max(1, min(self.q_per_kv, 2))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=96 if self.d_ff else 0,
            vocab_size=128,
            num_experts=8 if self.is_moe else 0,
            experts_per_token=2 if self.is_moe else 0,
            window_size=16 if self.window_size else 0,
            conv_width=min(self.conv_width, 4),
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    def smoke(self) -> "ShapeConfig":
        return ShapeConfig(self.name + "-smoke", 32, 4, self.kind)


# The four assigned LM shapes ------------------------------------------------
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applies(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (assignment rule)."""
    if shape.name.startswith("long_") and not arch.sub_quadratic:
        return False
    return True
