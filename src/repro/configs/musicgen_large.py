"""musicgen-large [audio] — decoder-only over EnCodec tokens (frontend STUB)
[arXiv:2306.05284; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    rope="none", norm="layernorm", act="gelu", glu=False,
    frontend="frame",
)
