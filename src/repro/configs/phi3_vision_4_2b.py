"""phi-3-vision-4.2b [vlm] — phi3-mini + CLIP (frontend STUB)
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    rope="full", norm="rmsnorm", act="silu", glu=True,
    frontend="patch",
)
