"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 2:1 [arXiv:2402.19427; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    window_size=2048, rope="full", norm="rmsnorm", act="gelu", glu=True,
    expand_factor=1.0, conv_width=4,
    tie_embeddings=True, sub_quadratic=True,
)
