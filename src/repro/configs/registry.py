"""Architecture registry: ``--arch <id>`` lookup + config wire format.

``arch_to_spec`` / ``shape_to_spec`` serialize a config for the sweep
backends' JobSpec wire format (process workers today, a remote/HTTP
backend next).  Deserialization prefers the registry — a spec whose name
resolves to a field-identical registry config (including the ``-smoke``
derivations) returns the canonical object — and falls back to rebuilding
the dataclass from its serialized fields for ad-hoc configs.
"""
from __future__ import annotations

import dataclasses
import json

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_applies

from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.stablelm_3b import CONFIG as _stablelm
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.chatglm3_6b import CONFIG as _chatglm
from repro.configs.starcoder2_3b import CONFIG as _starcoder
from repro.configs.phi3_vision_4_2b import CONFIG as _phi3v
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.recurrentgemma_2b import CONFIG as _rg
from repro.configs.musicgen_large import CONFIG as _musicgen

ARCHS = {c.name: c for c in (
    _xlstm, _stablelm, _granite, _chatglm, _starcoder,
    _phi3v, _qwen3, _kimi, _rg, _musicgen,
)}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_arch(name[: -len("-smoke")]).smoke()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name.endswith("-smoke"):
        return get_shape(name[: -len("-smoke")]).smoke()
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def _jsonable(d: dict) -> dict:
    """Normalize through JSON (tuples -> lists) for field comparison."""
    return json.loads(json.dumps(d, sort_keys=True, default=str))


def arch_to_spec(cfg: ArchConfig) -> dict:
    return {"name": cfg.name, "fields": _jsonable(dataclasses.asdict(cfg))}


def arch_from_spec(spec: dict) -> ArchConfig:
    try:
        cand = get_arch(spec["name"])
        if _jsonable(dataclasses.asdict(cand)) == _jsonable(spec["fields"]):
            return cand
    except KeyError:
        pass
    fields = dict(spec["fields"])
    fields["block_pattern"] = tuple(fields.get("block_pattern") or ("attn",))
    return ArchConfig(**fields)


def shape_to_spec(shape: ShapeConfig) -> dict:
    return {"name": shape.name, "fields": _jsonable(dataclasses.asdict(shape))}


def shape_from_spec(spec: dict) -> ShapeConfig:
    try:
        cand = get_shape(spec["name"])
        if _jsonable(dataclasses.asdict(cand)) == _jsonable(spec["fields"]):
            return cand
    except KeyError:
        pass
    return ShapeConfig(**spec["fields"])


def all_cells():
    """All 40 (arch x shape) cells, with applicability flag."""
    for a in ARCHS.values():
        for s in SHAPES.values():
            yield a, s, shape_applies(a, s)
