"""starcoder2-3b [dense] — GQA, RoPE, sliding window [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    window_size=4096, rope="full", norm="layernorm", act="gelu", glu=False,
    tie_embeddings=True,
)
