"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    rope="none", norm="layernorm", act="gelu", glu=False,
    expand_factor=2.0, conv_width=4,
    tie_embeddings=True, sub_quadratic=True,
)
