"""ComParX core: the paper's contribution (segmentation + multi-provider
hyper-parameter sweep + DB + fusion + black-box validation)."""
from repro.core.backends import (  # noqa: F401
    JobOutcome, JobSpec, ProcessBackend, Recorder, Scheduler, ThreadBackend,
    make_backend,
)
from repro.core.combinator import (  # noqa: F401
    Combination, GlobalKnobs, SweepSpec, enumerate_combinations,
    global_grid, load_sweep_json, paper_combination_count, row_cid,
    swept_knob_fields,
)
from repro.core.cost_model import CostTerms, Hardware, V5E  # noqa: F401
from repro.core.db import SweepDB  # noqa: F401
from repro.core.fusion import best_uniform, fuse, fuse_joint  # noqa: F401
from repro.core.meshspec import (  # noqa: F401
    LOCAL, MeshSpec, MeshUnsatisfiable, as_mesh_point,
)
from repro.core.plan import Plan, build_contexts, uniform_plan  # noqa: F401
from repro.core.segment import Segment, fragment  # noqa: F401
from repro.core.tuner import (  # noqa: F401
    BackendOptions, ComParTuner, SearchOptions, SweepReport,
)
