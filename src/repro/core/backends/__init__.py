"""Sweep scoring backends: Scheduler -> ScoringBackend -> Recorder.

``make_backend`` is the selection point for ``ComParTuner.sweep(
backend=...)``: ``"thread"`` (default, PR-1 semantics), ``"sequential"``
(thread with one worker, no pool), ``"process"`` (spawned workers, hard
preemptive timeouts), ``"remote"`` (ship jobs to a sweep scoring server
— ``backends/server.py`` — over HTTP; needs ``remote_url``).  With
``fallback=<local name>`` the remote backend is wrapped in a
:class:`FallbackBackend` that re-scores transiently failed jobs locally
in the same run (degraded mode).  ``retry`` is the unified
:class:`RetryPolicy`; ``token`` the remote server's shared secret.
"""
from repro.core.backends.base import (  # noqa: F401
    DONE, FAILED, PRUNED, STATUSES, WIRE_VERSION, IncumbentTracker, JobGroup,
    JobOutcome, JobSpec, RetryPolicy, ScoringBackend, WireVersionError,
    check_wire_version, executor_from_spec, executor_to_spec,
)
from repro.core.backends.fallback import FallbackBackend  # noqa: F401
from repro.core.backends.faults import (  # noqa: F401
    ChaosProxy, FaultPlan, FaultRule,
)
from repro.core.backends.process import ProcessBackend  # noqa: F401
from repro.core.backends.recorder import Recorder  # noqa: F401
from repro.core.backends.remote import RemoteBackend  # noqa: F401
from repro.core.backends.scheduler import (  # noqa: F401
    Scheduler, SweepWork, drive, env_key, mesh_key, shape_key,
)
from repro.core.backends.thread import ThreadBackend  # noqa: F401

BACKENDS = ("thread", "sequential", "process", "remote")


def make_backend(name, executor, cfg, shape, *, workers=1, prune=False,
                 prune_margin=0.1, timeout_s=None, db_path=None,
                 shape_key="", mesh_key="", remote_url=None, token=None,
                 retry=None, fallback=None):
    if name in (None, "thread"):
        return ThreadBackend(executor, cfg, shape, workers=workers,
                             prune=prune, prune_margin=prune_margin)
    if name == "sequential":
        return ThreadBackend(executor, cfg, shape, workers=1,
                             prune=prune, prune_margin=prune_margin)
    if name == "process":
        return ProcessBackend(executor, cfg, shape, workers=workers,
                              prune=prune, prune_margin=prune_margin,
                              timeout_s=timeout_s, db_path=db_path,
                              shape_key=shape_key, mesh_key=mesh_key,
                              retry=retry)
    if name == "remote":
        if not remote_url:
            raise ValueError("backend='remote' needs remote_url "
                             "(the sweep scoring server, e.g. "
                             "http://host:8477)")
        remote = RemoteBackend(executor, cfg, shape, url=remote_url,
                               prune=prune, prune_margin=prune_margin,
                               timeout_s=timeout_s, shape_key=shape_key,
                               mesh_key=mesh_key, retry=retry, token=token)
        if fallback is None:
            return remote
        if fallback == "remote":
            raise ValueError("fallback must be a LOCAL backend "
                             "(thread/sequential/process) — falling back "
                             "to the remote that just failed is a loop")
        local = make_backend(fallback, executor, cfg, shape,
                             workers=workers, prune=prune,
                             prune_margin=prune_margin, timeout_s=timeout_s,
                             db_path=db_path, shape_key=shape_key,
                             mesh_key=mesh_key, retry=retry)
        return FallbackBackend(remote, local)
    raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
