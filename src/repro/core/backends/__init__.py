"""Sweep scoring backends: Scheduler -> ScoringBackend -> Recorder.

``make_backend`` is the selection point for ``ComParTuner.sweep(
backend=...)``: ``"thread"`` (default, PR-1 semantics), ``"sequential"``
(thread with one worker, no pool), ``"process"`` (spawned workers, hard
preemptive timeouts).
"""
from repro.core.backends.base import (  # noqa: F401
    DONE, FAILED, PRUNED, STATUSES, IncumbentTracker, JobGroup, JobOutcome,
    JobSpec, ScoringBackend, executor_from_spec, executor_to_spec,
)
from repro.core.backends.process import ProcessBackend  # noqa: F401
from repro.core.backends.recorder import Recorder  # noqa: F401
from repro.core.backends.scheduler import (  # noqa: F401
    Scheduler, SweepWork, env_key, mesh_key, shape_key,
)
from repro.core.backends.thread import ThreadBackend  # noqa: F401

BACKENDS = ("thread", "sequential", "process")


def make_backend(name, executor, cfg, shape, *, workers=1, prune=False,
                 prune_margin=0.1, timeout_s=None, db_path=None,
                 shape_key="", mesh_key=""):
    if name in (None, "thread"):
        return ThreadBackend(executor, cfg, shape, workers=workers,
                             prune=prune, prune_margin=prune_margin)
    if name == "sequential":
        return ThreadBackend(executor, cfg, shape, workers=1,
                             prune=prune, prune_margin=prune_margin)
    if name == "process":
        return ProcessBackend(executor, cfg, shape, workers=workers,
                              prune=prune, prune_margin=prune_margin,
                              timeout_s=timeout_s, db_path=db_path,
                              shape_key=shape_key, mesh_key=mesh_key)
    raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
