"""Scoring-backend wire format + shared machinery.

The sweep pipeline is three composable stages:

    Scheduler  ->  ScoringBackend  ->  Recorder

The Scheduler turns registered (segment, combination) rows into unique
:class:`JobSpec` programs (structural grouping, validation, persistent
cache resolution, lower-bound ordering).  A ScoringBackend scores them —
in threads, in spawned worker processes, or (next) on a remote service —
and yields one :class:`JobOutcome` per job.  The Recorder fans outcomes
back out to member rows and sinks them into the DB in batched
transactions.

``JobSpec`` / ``JobOutcome`` are a *serializable* wire format: pure-JSON
``to_json``/``from_json`` on both, arch/shape reconstructed from the
config registry by name (``repro.configs.registry.arch_from_spec``).
A process worker and a future HTTP worker speak exactly this format.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional, Sequence, Tuple

from repro.core.combinator import Combination, GlobalKnobs
from repro.core.segment import Segment

#: version of the JSON wire format: JobSpec/JobOutcome payloads, the
#: process-worker init message, and the remote scoring service's HTTP
#: envelope all carry it.  Bump on any incompatible change — a server
#: must reject (not guess at) payloads from a different format era,
#: because a misdecoded spec would be scored and *cached* under the
#: wrong key on every host sharing that server.
WIRE_VERSION = 1


class WireVersionError(ValueError):
    """A wire payload was produced by an incompatible format version."""


def check_wire_version(payload: Dict):
    """Validate an envelope's ``v`` field against :data:`WIRE_VERSION`."""
    v = payload.get("v")
    if v != WIRE_VERSION:
        raise WireVersionError(
            f"wire format version mismatch: payload has v={v!r}, "
            f"this end speaks v={WIRE_VERSION}")


#: structured outcome taxonomy (replaces string-matched statuses)
DONE = "done"          # compiled + analyzed; cost attached
FAILED = "failed"      # could not be scored; ``transient`` says whether
                       # the failure is deterministic (cacheable) or a
                       # deadline/crash (retryable, never cached)
PRUNED = "pruned"      # skipped by the exact lower-bound prune
STATUSES = (DONE, FAILED, PRUNED)


@dataclass
class JobSpec:
    """One *unique* program to score (the process/remote wire format).

    ``knobs`` is the GlobalKnobs point the program is built under (None
    = score without knob effects, the pre-knob behavior for hand-built
    jobs).  ``segments`` lists the incumbent *scopes* whose rows share
    this program — Scheduler-built jobs use ``"<knob kid>/<segment>"``
    keys so pruning compares against the right knob point's incumbents;
    the tracker treats them as opaque strings.  ``signature``/``eff_cid``
    are the group's persistent-cache key components, shipped so a worker
    can consult the shared score cache itself.  Field layout is
    compatible with :class:`repro.core.executor.SweepJob` so the thread
    backend can feed specs straight into ``ParallelSweepRunner``.
    """
    key: str
    seg: Segment
    combo: Combination
    segments: Tuple[str, ...] = ()
    bound_s: float = 0.0
    signature: str = ""
    eff_cid: str = ""
    knobs: Optional[GlobalKnobs] = None

    def to_json(self) -> Dict:
        return {"key": self.key, "seg": self.seg.to_json(),
                "combo": self.combo.to_json(),
                "segments": list(self.segments), "bound_s": self.bound_s,
                "signature": self.signature, "eff_cid": self.eff_cid,
                "knobs": self.knobs.to_json()
                if self.knobs is not None else None}

    @classmethod
    def from_json(cls, d: Dict) -> "JobSpec":
        return cls(d["key"], Segment.from_json(d["seg"]),
                   Combination.from_json(d["combo"]),
                   tuple(d.get("segments") or ()),
                   float(d.get("bound_s", 0.0)),
                   d.get("signature", ""), d.get("eff_cid", ""),
                   GlobalKnobs.from_json(d["knobs"])
                   if d.get("knobs") else None)


@dataclass
class JobOutcome:
    """The result of scoring one JobSpec.

    ``transient`` marks deadline overruns and worker crashes: outcomes
    that depend on machine load, the time budget, or worker health — a
    retry with a bigger budget must be possible, so transient failures
    are never cached.  ``cached`` marks outcomes a worker served from the
    persistent score cache (no compile happened).  ``attempts`` counts
    dispatches, >1 after a requeue.
    """
    key: str
    status: str                      # DONE | FAILED | PRUNED
    cost: Optional[Dict] = None      # CostTerms.as_dict()
    error: str = ""
    transient: bool = False
    cached: bool = False
    attempts: int = 1

    def to_json(self) -> Dict:
        return {"key": self.key, "status": self.status, "cost": self.cost,
                "error": self.error, "transient": self.transient,
                "cached": self.cached, "attempts": self.attempts}

    @classmethod
    def from_json(cls, d: Dict) -> "JobOutcome":
        return cls(d["key"], d["status"], d.get("cost"),
                   d.get("error", ""), bool(d.get("transient", False)),
                   bool(d.get("cached", False)), int(d.get("attempts", 1)))


@dataclass
class JobGroup:
    """All pending (segment, row-cid) rows that share one program.

    ``knobs`` is the representative knob point the program is built
    under (any member's point projects to the same program, by the
    effective-cid grouping).  ``scopes`` are the ``"<knob kid>/<segment>"``
    incumbent keys of every member — the per-knob-point pruning scope.
    """
    seg: Segment
    combo: Combination
    signature: str
    eff_cid: str
    members: list = field(default_factory=list)   # [(segment, row_cid), ...]
    knobs: Optional[GlobalKnobs] = None
    scopes: set = field(default_factory=set)


class IncumbentTracker:
    """Thread-safe per-scope incumbent bests + the exact prune check.

    A job is pruned only when its analytic lower bound exceeds the
    incumbent best of *every* member scope by ``prune_margin`` — since
    bound <= true score, a pruned job can never be any scope's argmin.
    Scope keys are opaque strings; Scheduler-built jobs use
    ``"<knob kid>/<segment>"`` so an incumbent from one knob point never
    prunes another point's rows (each knob point needs its own
    per-segment argmin for the joint solve to stay exact).
    """

    def __init__(self, prune: bool = False, prune_margin: float = 0.1):
        self.prune = prune
        self.prune_margin = prune_margin
        self._lock = threading.Lock()
        self._best: Dict[str, float] = {}

    def seed(self, incumbents: Optional[Dict[str, float]]):
        if not incumbents:
            return
        with self._lock:
            for s, v in incumbents.items():
                cur = self._best.get(s)
                if cur is None or v < cur:
                    self._best[s] = v

    def observe(self, segments: Sequence[str], total_s: float):
        with self._lock:
            for s in segments:
                cur = self._best.get(s)
                if cur is None or total_s < cur:
                    self._best[s] = total_s

    def pruned(self, job: JobSpec) -> bool:
        if not self.prune or job.bound_s <= 0.0 or not job.segments:
            return False
        with self._lock:
            return all(
                s in self._best and
                job.bound_s > self._best[s] * (1.0 + self.prune_margin)
                for s in job.segments)


class ScoringBackend:
    """Interface: score JobSpecs, yield JobOutcomes as they complete."""

    name = "?"

    def run(self, jobs: Sequence[JobSpec],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobOutcome]:
        raise NotImplementedError

    def close(self):
        """Release workers/resources; idempotent."""


def executor_to_spec(executor) -> Dict:
    """Serialize an executor for worker-side reconstruction."""
    import dataclasses

    from repro.core.executor import (CrashExecutor, DryRunExecutor,
                                     SleepExecutor, WallClockExecutor)
    if getattr(executor, "mesh", None) is not None:
        # a worker would rebuild the executor mesh-less and silently
        # score different programs under the meshed cache key; the tuner
        # falls back to the thread backend for meshed sweeps — a direct
        # ProcessBackend construction must fail just as loudly
        raise TypeError(
            f"{type(executor).__name__} holds a mesh: device handles "
            "don't serialize, use the thread backend for meshed sweeps")
    if isinstance(executor, DryRunExecutor):
        # hw is cache identity (cache_tag embeds hw.name): the worker
        # must score with the parent's hardware model, not the default
        return {"kind": "dryrun", "timeout_s": executor.timeout_s,
                "hw": dataclasses.asdict(executor.hw)}
    if isinstance(executor, WallClockExecutor):
        return {"kind": "wallclock", "timeout_s": executor.timeout_s,
                "repeats": executor.repeats}
    if isinstance(executor, SleepExecutor):
        return {"kind": "sleep", "sleep_s": executor.sleep_s,
                "timeout_s": executor.timeout_s}
    if isinstance(executor, CrashExecutor):
        return {"kind": "crash", "timeout_s": executor.timeout_s}
    raise TypeError(f"no wire spec for executor {type(executor).__name__} "
                    f"(process backend supports dryrun/wallclock)")


def executor_from_spec(spec: Dict, *, allow_test: bool = False):
    """Rebuild an executor in a worker process (mesh-less: meshes are not
    serializable, so the process backend is gated to local sweeps).

    ``allow_test`` admits the fault-injection executors (sleep/crash).
    Local process workers pass True — they trust their parent (same
    machine, same user).  A remote/HTTP backend deserializing *client*
    specs must keep the default: ``{"kind": "crash"}`` from an untrusted
    client would otherwise be a remote kill switch for every worker.
    """
    from repro.core.cost_model import Hardware, V5E
    from repro.core.executor import (CrashExecutor, DryRunExecutor,
                                     SleepExecutor, WallClockExecutor)
    kind = spec["kind"]
    if kind == "dryrun":
        hw = Hardware(**spec["hw"]) if spec.get("hw") else V5E
        return DryRunExecutor(None, hw=hw, timeout_s=spec.get("timeout_s"))
    if kind == "wallclock":
        return WallClockExecutor(None, repeats=spec.get("repeats", 5),
                                 timeout_s=spec.get("timeout_s"))
    if allow_test and kind == "sleep":
        return SleepExecutor(sleep_s=spec.get("sleep_s", 3600.0),
                             timeout_s=spec.get("timeout_s"))
    if allow_test and kind == "crash":
        return CrashExecutor(timeout_s=spec.get("timeout_s"))
    raise ValueError(f"unknown executor kind {kind!r}")
