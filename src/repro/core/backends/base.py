"""Scoring-backend wire format + shared machinery.

The sweep pipeline is three composable stages:

    Scheduler  ->  ScoringBackend  ->  Recorder

The Scheduler turns registered (segment, combination) rows into unique
:class:`JobSpec` programs (structural grouping, validation, persistent
cache resolution, lower-bound ordering).  A ScoringBackend scores them —
in threads, in spawned worker processes, or (next) on a remote service —
and yields one :class:`JobOutcome` per job.  The Recorder fans outcomes
back out to member rows and sinks them into the DB in batched
transactions.

``JobSpec`` / ``JobOutcome`` are a *serializable* wire format: pure-JSON
``to_json``/``from_json`` on both, arch/shape reconstructed from the
config registry by name (``repro.configs.registry.arch_from_spec``).
A process worker and a future HTTP worker speak exactly this format.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional, Sequence, Tuple

from repro.core.combinator import Combination, GlobalKnobs
from repro.core.meshspec import MeshSpec
from repro.core.segment import Segment

#: version of the JSON wire format: JobSpec/JobOutcome payloads, the
#: process-worker init message, and the remote scoring service's HTTP
#: envelope all carry it.  Bump on any incompatible change — a server
#: must reject (not guess at) payloads from a different format era,
#: because a misdecoded spec would be scored and *cached* under the
#: wrong key on every host sharing that server.
#:
#: v2 added the mesh axis: ``JobSpec.mesh``/``mesh_key`` and the
#: executor init spec's ``mesh`` (a MeshSpec, rebuilt by whichever
#: process scores the job).  A v1 server would silently score meshed
#: jobs mesh-less and cache them under the meshed key — exactly the
#: misdecode the version gate exists to prevent.
#:
#: v3 added failure accounting to ``JobOutcome``: ``kind`` (the failure
#: taxonomy bucket — "deadline"/"crash"/"mesh"/"unreachable"/"server")
#: and ``fallback`` (scored by a local backend after the remote retry
#: budget ran out).  A v2 peer would silently drop both fields and a
#: degraded run would report itself as healthy.
#:
#: Note: ``static`` outcomes (PlanLint rejections, PR 9) are settled by
#: the Scheduler *before* a JobSpec exists — they never appear in
#: JobSpec/JobOutcome payloads and are never cached, so the wire format
#: is unchanged and needs no bump.
WIRE_VERSION = 3


class WireVersionError(ValueError):
    """A wire payload was produced by an incompatible format version."""


def check_wire_version(payload: Dict):
    """Validate an envelope's ``v`` field against :data:`WIRE_VERSION`."""
    v = payload.get("v")
    if v != WIRE_VERSION:
        raise WireVersionError(
            f"wire format version mismatch: payload has v={v!r}, "
            f"this end speaks v={WIRE_VERSION}")


#: structured outcome taxonomy (replaces string-matched statuses)
DONE = "done"          # compiled + analyzed; cost attached
FAILED = "failed"      # could not be scored; ``transient`` says whether
                       # the failure is deterministic (cacheable) or a
                       # deadline/crash (retryable, never cached)
PRUNED = "pruned"      # skipped by the exact lower-bound prune
STATUSES = (DONE, FAILED, PRUNED)


@dataclass
class JobSpec:
    """One *unique* program to score (the process/remote wire format).

    ``knobs`` is the GlobalKnobs point the program is built under (None
    = score without knob effects, the pre-knob behavior for hand-built
    jobs).  ``segments`` lists the incumbent *scopes* whose rows share
    this program — Scheduler-built jobs use ``"<knob kid>/<segment>"``
    keys (``"<mesh mid>/<knob kid>/<segment>"`` when the mesh is swept)
    so pruning compares against the right point's incumbents;
    the tracker treats them as opaque strings.  ``signature``/``eff_cid``
    are the group's persistent-cache key components, shipped so a worker
    can consult the shared score cache itself.

    ``slack_s`` is the boundary-cost pruning allowance: when fusion
    charges layout-transition costs (``boundary_costs=True``), a
    combination may lose the per-segment comparison yet still win the
    Viterbi chain by avoiding reshards, so the exact prune condition
    loosens to ``bound > incumbent * (1 + margin) + slack_s`` where
    ``slack_s`` is (n_segments - 1) times the largest possible single
    boundary cost (``fusion.max_boundary_cost_s``) — the most any
    chain total can sit above the sum of its per-segment minima.
    ``0.0`` (the default, and the value under per-segment-argmin
    fusion) restores the strict check.  Wire-tolerant: absent on old
    payloads -> 0.0, which only prunes *less*, never wrongly.

    ``mesh`` is the swept topology point the program must be built
    under, as a declarative :class:`~repro.core.meshspec.MeshSpec` —
    whichever process scores the job materializes it against its own
    local devices (``meshspec.cached_mesh``).  ``None`` = the executor's
    own (fixed) mesh, which travels in the executor init spec; the local
    point of a swept axis is the explicit ``MeshSpec(())``.  ``mesh_key``
    is the score-cache environment column for this job's point (``""`` =
    the pipeline default from the init message) — shipped, not
    re-derived, so client and server can never key the same score
    differently.  Field layout is compatible with
    :class:`repro.core.executor.SweepJob` so the thread backend can feed
    specs straight into ``ParallelSweepRunner``.
    """
    key: str
    seg: Segment
    combo: Combination
    segments: Tuple[str, ...] = ()
    bound_s: float = 0.0
    signature: str = ""
    eff_cid: str = ""
    knobs: Optional[GlobalKnobs] = None
    mesh: Optional[MeshSpec] = None
    mesh_key: str = ""
    slack_s: float = 0.0

    def to_json(self) -> Dict:
        return {"key": self.key, "seg": self.seg.to_json(),
                "combo": self.combo.to_json(),
                "segments": list(self.segments), "bound_s": self.bound_s,
                "signature": self.signature, "eff_cid": self.eff_cid,
                "knobs": self.knobs.to_json()
                if self.knobs is not None else None,
                "mesh": self.mesh.to_json()
                if self.mesh is not None else None,
                "mesh_key": self.mesh_key, "slack_s": self.slack_s}

    @classmethod
    def from_json(cls, d: Dict) -> "JobSpec":
        return cls(d["key"], Segment.from_json(d["seg"]),
                   Combination.from_json(d["combo"]),
                   tuple(d.get("segments") or ()),
                   float(d.get("bound_s", 0.0)),
                   d.get("signature", ""), d.get("eff_cid", ""),
                   GlobalKnobs.from_json(d["knobs"])
                   if d.get("knobs") else None,
                   MeshSpec.from_json(d["mesh"])
                   if d.get("mesh") else None,
                   d.get("mesh_key", ""),
                   float(d.get("slack_s", 0.0)))


@dataclass
class JobOutcome:
    """The result of scoring one JobSpec.

    ``transient`` marks deadline overruns and worker crashes: outcomes
    that depend on machine load, the time budget, or worker health — a
    retry with a bigger budget must be possible, so transient failures
    are never cached.  ``cached`` marks outcomes a worker served from the
    persistent score cache (no compile happened).  ``attempts`` counts
    dispatches, >1 after a requeue.

    ``kind`` buckets failures for the SweepReport's per-kind counts:
    "deadline" (budget overrun), "crash" (worker died twice holding the
    job), "mesh" (this host can't satisfy the swept mesh point),
    "unreachable" (remote server gone past the retry budget), "server"
    (remote server failed the batch).  ``""`` on success or when the
    producing backend predates the taxonomy — the Recorder then falls
    back to "transient"/"deterministic".  ``fallback`` marks outcomes
    re-scored by a local backend after the remote budget ran out.
    """
    key: str
    status: str                      # DONE | FAILED | PRUNED
    cost: Optional[Dict] = None      # CostTerms.as_dict()
    error: str = ""
    transient: bool = False
    cached: bool = False
    attempts: int = 1
    kind: str = ""
    fallback: bool = False

    def to_json(self) -> Dict:
        return {"key": self.key, "status": self.status, "cost": self.cost,
                "error": self.error, "transient": self.transient,
                "cached": self.cached, "attempts": self.attempts,
                "kind": self.kind, "fallback": self.fallback}

    @classmethod
    def from_json(cls, d: Dict) -> "JobOutcome":
        return cls(d["key"], d["status"], d.get("cost"),
                   d.get("error", ""), bool(d.get("transient", False)),
                   bool(d.get("cached", False)), int(d.get("attempts", 1)),
                   d.get("kind", ""), bool(d.get("fallback", False)))


@dataclass
class JobGroup:
    """All pending (segment, row-cid) rows that share one program.

    ``knobs`` is the representative knob point the program is built
    under (any member's point projects to the same program, by the
    effective-cid grouping).  ``scopes`` are the ``"<knob kid>/<segment>"``
    incumbent keys of every member — the per-knob-point pruning scope
    (mesh-qualified when the mesh is swept).  ``mesh`` is the swept mesh
    point (``None`` = unswept, the executor's fixed mesh) and
    ``mesh_key`` its score-cache environment column (``""`` = the
    pipeline default) — the Recorder banks this group's score under it.
    """
    seg: Segment
    combo: Combination
    signature: str
    eff_cid: str
    members: list = field(default_factory=list)   # [(segment, row_cid), ...]
    knobs: Optional[GlobalKnobs] = None
    scopes: set = field(default_factory=set)
    mesh: Optional[MeshSpec] = None
    mesh_key: str = ""


@dataclass(frozen=True)
class RetryPolicy:
    """One retry contract shared across the pipeline's recovery layers.

    * remote ``_request``: retry transport/5xx failures for up to
      ``budget_s`` seconds, pausing ``pause_s(attempt)`` between tries —
      exponential from ``base_s`` capped at ``cap_s``, with up to
      ``jitter`` (a fraction of the pause) shaved off at random so N
      clients recovering from one server restart don't re-poll in
      lockstep.
    * process requeue: a job whose worker dies is re-dispatched until it
      has been attempted ``max_attempts`` times.
    * scheduler: transient FAILED outcomes are re-dispatched for
      ``sweep_retries`` extra rounds before the sweep concludes.

    Frozen (hashable): tuner engine caching keys process pools by their
    kwargs, and this rides along.
    """
    budget_s: float = 30.0       # per-request wall-clock retry budget
    base_s: float = 0.25         # first backoff pause
    cap_s: float = 2.0           # backoff pause ceiling
    jitter: float = 0.5          # fraction of the pause randomly shaved
    max_attempts: int = 2        # process-backend dispatches per job
    sweep_retries: int = 1       # scheduler-level transient retry rounds

    def pause_s(self, attempt: int, rng=None) -> float:
        """Backoff pause before retry ``attempt`` (0-based), jittered."""
        import random as _random
        p = min(self.cap_s, self.base_s * (2.0 ** attempt))
        if not self.jitter:
            return p
        r = (rng if rng is not None else _random).random()
        return p * (1.0 - self.jitter * r)


class IncumbentTracker:
    """Thread-safe per-scope incumbent bests + the exact prune check.

    A job is pruned only when its analytic lower bound exceeds the
    incumbent best of *every* member scope by ``prune_margin`` — since
    bound <= true score, a pruned job can never be any scope's argmin.
    Scope keys are opaque strings; Scheduler-built jobs use
    ``"<knob kid>/<segment>"`` so an incumbent from one knob point never
    prunes another point's rows (each knob point needs its own
    per-segment argmin for the joint solve to stay exact).

    ``job.slack_s`` (boundary-cost fusion) is added on the incumbent
    side of the check: if the pruned combination's bound still exceeds
    every scope's best plus the largest possible total boundary-cost
    divergence of a chain, no Viterbi path through it can beat the
    chain built from the per-segment bests — so the joint argmin is
    unchanged.  Proof sketch: any chain through combo c on segment s
    costs >= bound(c) + sum of the other segments' true minima; the
    optimal chain costs <= sum of all per-segment minima +
    (n_segments - 1) * max_boundary_cost.
    """

    def __init__(self, prune: bool = False, prune_margin: float = 0.1):
        self.prune = prune
        self.prune_margin = prune_margin
        self._lock = threading.Lock()
        self._best: Dict[str, float] = {}

    def seed(self, incumbents: Optional[Dict[str, float]]):
        if not incumbents:
            return
        with self._lock:
            for s, v in incumbents.items():
                cur = self._best.get(s)
                if cur is None or v < cur:
                    self._best[s] = v

    def observe(self, segments: Sequence[str], total_s: float):
        with self._lock:
            for s in segments:
                cur = self._best.get(s)
                if cur is None or total_s < cur:
                    self._best[s] = total_s

    def pruned(self, job: JobSpec) -> bool:
        if not self.prune or job.bound_s <= 0.0 or not job.segments:
            return False
        with self._lock:
            return all(
                s in self._best and
                job.bound_s > (self._best[s] * (1.0 + self.prune_margin)
                               + job.slack_s)
                for s in job.segments)


class ScoringBackend:
    """Interface: score JobSpecs, yield JobOutcomes as they complete."""

    name = "?"

    def run(self, jobs: Sequence[JobSpec],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobOutcome]:
        raise NotImplementedError

    def close(self):
        """Release workers/resources; idempotent."""


def executor_to_spec(executor) -> Dict:
    """Serialize an executor for worker-side reconstruction.

    A fixed-mesh executor serializes its mesh as a declarative
    :class:`~repro.core.meshspec.MeshSpec` (device handles never cross
    the wire); :func:`executor_from_spec` materializes it against the
    *scoring* process's local devices — so meshed sweeps run on the
    process and remote backends exactly like local ones.
    """
    import dataclasses

    from repro.core.executor import (CrashExecutor, DryRunExecutor,
                                     SleepExecutor, WallClockExecutor)
    mesh = getattr(executor, "mesh", None)
    mesh_spec = MeshSpec.from_mesh(mesh).to_json() if mesh is not None \
        else None
    if isinstance(executor, DryRunExecutor):
        # hw is cache identity (cache_tag embeds hw.name): the worker
        # must score with the parent's hardware model, not the default
        return {"kind": "dryrun", "timeout_s": executor.timeout_s,
                "hw": dataclasses.asdict(executor.hw), "mesh": mesh_spec}
    if isinstance(executor, WallClockExecutor):
        return {"kind": "wallclock", "timeout_s": executor.timeout_s,
                "repeats": executor.repeats, "mesh": mesh_spec}
    if isinstance(executor, SleepExecutor):
        return {"kind": "sleep", "sleep_s": executor.sleep_s,
                "timeout_s": executor.timeout_s}
    if isinstance(executor, CrashExecutor):
        return {"kind": "crash", "timeout_s": executor.timeout_s}
    raise TypeError(f"no wire spec for executor {type(executor).__name__} "
                    f"(process backend supports dryrun/wallclock)")


def executor_from_spec(spec: Dict, *, allow_test: bool = False):
    """Rebuild an executor in the scoring process, materializing its
    fixed mesh (if any) against local devices —
    :class:`~repro.core.meshspec.MeshUnsatisfiable` if this host can't
    (the scoring server maps that to HTTP 400 at submit).

    ``allow_test`` admits the fault-injection executors (sleep/crash).
    Local process workers pass True — they trust their parent (same
    machine, same user).  A remote/HTTP backend deserializing *client*
    specs must keep the default: ``{"kind": "crash"}`` from an untrusted
    client would otherwise be a remote kill switch for every worker.
    """
    from repro.core.cost_model import Hardware, V5E
    from repro.core.executor import (CrashExecutor, DryRunExecutor,
                                     SleepExecutor, WallClockExecutor)
    from repro.core.meshspec import cached_mesh
    kind = spec["kind"]
    mesh = cached_mesh(MeshSpec.from_json(spec["mesh"])) \
        if spec.get("mesh") else None
    if kind == "dryrun":
        hw = Hardware(**spec["hw"]) if spec.get("hw") else V5E
        return DryRunExecutor(mesh, hw=hw, timeout_s=spec.get("timeout_s"))
    if kind == "wallclock":
        return WallClockExecutor(mesh, repeats=spec.get("repeats", 5),
                                 timeout_s=spec.get("timeout_s"))
    if allow_test and kind == "sleep":
        return SleepExecutor(sleep_s=spec.get("sleep_s", 3600.0),
                             timeout_s=spec.get("timeout_s"))
    if allow_test and kind == "crash":
        return CrashExecutor(timeout_s=spec.get("timeout_s"))
    raise ValueError(f"unknown executor kind {kind!r}")
