"""Graceful degradation: re-score remote losses locally, in the same run.

Before this backend, a scoring-server outage had exactly one shape: the
:class:`~repro.core.backends.remote.RemoteBackend` burned its retry
budget, failed every pending job ``transient=True``, and the sweep
quietly fused a plan from whatever survived — recovery deferred to
"a later sweep".  :class:`FallbackBackend` closes that gap: it streams
the primary's outcomes through, collects the transient failures, and
re-scores them on a *local* backend before the run concludes.  The
degraded path is loud, not silent — every fallback outcome is flagged
``fallback=True`` and the Recorder surfaces the count as
``SweepReport.n_fallback_local``.

What is and is not retried locally:

* transient FAILED outcomes (server unreachable, server-side batch
  failure, deadline double-loss) — retried: they are verdicts on the
  *infrastructure*, not the combination;
* deterministic FAILED / DONE / PRUNED outcomes — passed through: the
  remote's verdict stands (re-scoring a deterministic failure locally
  would just re-prove it, and DONE needs no help);
* protocol errors (HTTP 4xx, wire-version mismatch, bad token) —
  raised: fallback exists to absorb outages, never to paper over bugs.

Attempt accounting carries across the seam: a job the remote dispatched
twice and the local backend scored on the third try reports
``attempts=3``.
"""
from __future__ import annotations

import logging
from typing import Dict, Iterator, List, Optional, Sequence

from repro.core.backends.base import (FAILED, JobOutcome, JobSpec,
                                      ScoringBackend)

log = logging.getLogger("repro.backends.fallback")


class FallbackBackend(ScoringBackend):
    """Wrap a primary (remote) backend over a local one: jobs the
    primary fails transiently are re-scored locally in the same run."""

    name = "fallback"

    def __init__(self, primary: ScoringBackend, local: ScoringBackend):
        self.primary = primary
        self.local = local
        self.n_fallback = 0     # jobs the local backend picked up, last run

    def run(self, jobs: Sequence[JobSpec],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobOutcome]:
        self.n_fallback = 0
        by_key = {j.key: j for j in jobs}
        retry: List[JobSpec] = []
        prior: Dict[str, int] = {}
        for out in self.primary.run(jobs, incumbents):
            if out.status == FAILED and out.transient \
                    and out.key in by_key:
                retry.append(by_key[out.key])
                prior[out.key] = out.attempts
                continue
            yield out
        if not retry:
            return
        self.n_fallback = len(retry)
        log.warning("primary backend %s failed %d job(s) transiently: "
                    "re-scoring locally on %s", self.primary.name,
                    len(retry), self.local.name)
        for out in self.local.run(retry, incumbents):
            out.fallback = True
            out.attempts += prior.get(out.key, 1)
            yield out

    def close(self):
        try:
            self.primary.close()
        finally:
            self.local.close()
