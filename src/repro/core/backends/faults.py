"""Deterministic fault injection for the scoring pipeline.

The failure contracts of ``remote.py`` / ``process.py`` / ``server.py``
("an unreachable server is transient", "a killed worker requeues once",
"a restart mid-batch is recovered by resubmission") are only worth the
docstrings they are written in if every one of them is *executable*.
This module makes them so, in two layers driven by one seeded plan:

* :class:`FaultPlan` — a reproducible schedule of faults.  Each named
  *injection point* ("proxy:/v1/submit", "process.kill_worker",
  "recorder.flush") counts its events; rules fire on explicit indices
  (``at=``), periodically (``every=``), or on a seeded pseudo-random
  fraction (``rate=``) whose decisions are a pure function of
  ``(seed, point, event index)`` — the same plan replays the same
  faults, run after run, host after host.
* :class:`ChaosProxy` — a stdlib HTTP proxy that sits between a
  :class:`~repro.core.backends.remote.RemoteBackend` and the scoring
  server and, per request, can drop the connection, delay past the
  client's timeout, reply 5xx, truncate the body mid-reply, or corrupt
  the JSON — every wire-level failure mode the client's retry loop
  claims to survive.  An unreachable upstream (the server restarting
  under it) is surfaced as HTTP 502, which the client treats as
  transient.

In-process points are consumed by the pipeline itself when handed a
plan: ``ProcessBackend(fault_plan=...)`` kills the worker holding the
Nth dispatched job ("process.kill_worker"), and
``Recorder(fault_plan=...)`` raises out of the Nth flush
("recorder.flush").  Production code paths pay one ``is None`` check.

The invariant the chaos suite (``tests/test_faults.py``) drives with
these tools: under ANY fault schedule the sweep terminates, the fused
plan is byte-identical to the fault-free sequential baseline whenever
all jobs eventually score, and no injected failure ever writes a
``score_cache`` row.
"""
from __future__ import annotations

import hashlib
import json
import logging
import threading
import time
import urllib.error
import urllib.request
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Sequence, Tuple

log = logging.getLogger("repro.backends.faults")

# --- fault kinds -------------------------------------------------------------
#: wire-level kinds, applied by the ChaosProxy
DROP = "drop"            # close the connection without any reply
DELAY = "delay"          # sleep ``delay_s`` before forwarding
ERROR = "error"          # reply HTTP ``status`` (default 500) instead
TRUNCATE = "truncate"    # declare the full Content-Length, send half, close
CORRUPT = "corrupt"      # reply 200 with a non-JSON body
#: in-process kinds, applied at pipeline injection points
KILL = "kill"            # kill the process-backend worker holding the job
RAISE = "raise"          # raise RuntimeError at the injection point
KINDS = (DROP, DELAY, ERROR, TRUNCATE, CORRUPT, KILL, RAISE)


@dataclass(frozen=True)
class FaultRule:
    """One fault trigger at one injection point.

    Fires when the point's 1-based event counter matches any explicit
    ``at`` index, is a multiple of ``every``, or falls under the seeded
    pseudo-random ``rate`` (deterministic per (plan seed, point, event));
    ``limit`` caps total firings (0 = unlimited)."""
    kind: str
    at: Tuple[int, ...] = ()
    every: int = 0
    rate: float = 0.0
    limit: int = 0
    delay_s: float = 0.0
    status: int = 500


class FaultPlan:
    """A seeded, thread-safe, replayable schedule of faults.

    ``rules`` maps injection-point names to rule sequences.  Every call
    to :meth:`fires` counts one event at that point and returns the
    first rule that triggers (or ``None``); each firing is appended to
    :attr:`events` as ``(point, event index, kind)`` so tests can assert
    the schedule actually executed.
    """

    def __init__(self, rules: Dict[str, Sequence[FaultRule]], *,
                 seed: int = 0):
        self.seed = seed
        self.rules = {p: tuple(rs) for p, rs in rules.items()}
        self._lock = threading.Lock()
        self._n: Dict[str, int] = {}
        self._fired: Dict[Tuple[str, int], int] = {}
        self.events: List[Tuple[str, int, str]] = []

    def fires(self, point: str) -> Optional[FaultRule]:
        """Count one event at ``point``; return the triggered rule."""
        with self._lock:
            n = self._n.get(point, 0) + 1
            self._n[point] = n
            for i, rule in enumerate(self.rules.get(point, ())):
                fired = self._fired.get((point, i), 0)
                if rule.limit and fired >= rule.limit:
                    continue
                if self._matches(rule, point, n, i):
                    self._fired[(point, i)] = fired + 1
                    self.events.append((point, n, rule.kind))
                    return rule
        return None

    def _matches(self, rule: FaultRule, point: str, n: int, i: int) -> bool:
        if n in rule.at:
            return True
        if rule.every and n % rule.every == 0:
            return True
        if rule.rate:
            blob = f"{self.seed}:{point}:{i}:{n}".encode()
            h = hashlib.sha256(blob).digest()
            return int.from_bytes(h[:8], "big") / 2.0 ** 64 < rule.rate
        return False

    def reset(self):
        """Rewind every counter so the same schedule replays."""
        with self._lock:
            self._n = {}
            self._fired = {}
            self.events = []


# --- the chaos HTTP proxy ----------------------------------------------------

#: body served for CORRUPT replies — bytes that can never decode as JSON
_GARBAGE = b'\xff\xfe{"chaos": not json'


class ChaosProxy:
    """A fault-injecting HTTP proxy in front of a scoring server.

    Forwards every request to ``upstream`` verbatim (method, path,
    query, body, Content-Type/Authorization headers) unless the plan
    fires for the request's injection point.  Two points are consulted
    per request, each with its own counter: the route-specific
    ``"proxy:<path>"`` (e.g. ``"proxy:/v1/submit"``) first, then the
    catch-all ``"proxy"``.

    ``retarget`` repoints the proxy at a different upstream — the chaos
    suite uses it to restart the scoring server mid-batch while the
    client keeps one stable URL.
    """

    def __init__(self, upstream: str, plan: Optional[FaultPlan] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 upstream_timeout_s: float = 90.0):
        self.plan = plan if plan is not None else FaultPlan({})
        self.upstream = upstream.rstrip("/")
        self.upstream_timeout_s = upstream_timeout_s
        self._httpd = ThreadingHTTPServer((host, port),
                                          _make_proxy_handler(self))
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> str:
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        log.info("chaos proxy %s -> %s", self.url, self.upstream)
        return self.url

    def retarget(self, upstream: str):
        self.upstream = upstream.rstrip("/")

    def close(self):
        # shutdown() only when serve_forever is live — it blocks forever
        # on a never-started server
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5)
            self._thread = None
        self._httpd.server_close()

    # ------------------------------------------------------------------
    def forward(self, method: str, path: str, body: Optional[bytes],
                headers: Dict[str, str]) -> Tuple[int, bytes]:
        """One upstream exchange; an unreachable upstream becomes a 502
        (the retryable verdict a real reverse proxy would give)."""
        req = urllib.request.Request(self.upstream + path, data=body,
                                     headers=headers, method=method)
        try:
            with urllib.request.urlopen(
                    req, timeout=self.upstream_timeout_s) as resp:
                return resp.status, resp.read()
        except urllib.error.HTTPError as e:
            return e.code, e.read()
        except (urllib.error.URLError, ConnectionError, OSError) as e:
            return 502, json.dumps(
                {"error": f"upstream {self.upstream} unreachable: {e}"}
            ).encode()


def _make_proxy_handler(app: ChaosProxy):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):
            log.debug("%s - %s", self.address_string(), fmt % args)

        def do_GET(self):
            self._serve("GET")

        def do_POST(self):
            self._serve("POST")

        def _serve(self, method: str):
            route = self.path.split("?", 1)[0]
            rule = app.plan.fires(f"proxy:{route}") or app.plan.fires("proxy")
            if rule is not None and rule.kind == DROP:
                # no reply at all: the client sees the connection die
                self.close_connection = True
                return
            if rule is not None and rule.kind == DELAY:
                time.sleep(rule.delay_s)
            if rule is not None and rule.kind == ERROR:
                return self._reply(rule.status, json.dumps(
                    {"error": f"injected HTTP {rule.status}"}).encode())
            body = None
            if method == "POST":
                n = int(self.headers.get("Content-Length", 0))
                body = self.rfile.read(n)
            headers = {h: self.headers[h]
                       for h in ("Content-Type", "Authorization")
                       if self.headers.get(h)}
            code, payload = app.forward(method, self.path, body, headers)
            if rule is not None and rule.kind == CORRUPT:
                payload = _GARBAGE
            if rule is not None and rule.kind == TRUNCATE:
                # full Content-Length, half the bytes: the client's read
                # raises IncompleteRead — retryable, like any torn reply
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload[:max(1, len(payload) // 2)])
                self.wfile.flush()
                self.close_connection = True
                return
            self._reply(code, payload)

        def _reply(self, code: int, payload: bytes):
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload)

    return Handler
