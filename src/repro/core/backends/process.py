"""Process backend: score unique programs in spawned worker processes.

Why a second backend exists at all: thread workers are GIL-bound during
jax tracing (compilation releases the GIL, tracing does not — tiny smoke
programs are tracing-dominated), and the off-main-thread deadline is
*soft*: a hung XLA compile still occupies its thread forever.  Spawned
workers fix both — true parallel tracing, and two layers of deadline:

* **in-worker hard deadline** — jobs run on the worker process's main
  thread, so the executor's SIGALRM deadline actually interrupts a hung
  Python-level compile (graceful: the worker reports a transient failure
  and stays warm);
* **parent-side kill** — the backstop for hangs SIGALRM cannot reach
  (native code that never returns to the interpreter): a worker busy past
  ``timeout_s`` wall-clock is terminated, the job is requeued once onto
  another worker, and on a second loss recorded as a **transient**
  failure.  The sweep can never hang on one combination.

Worker lifecycle: workers are warm (one jax import + executor per
process, reused across jobs AND across successive ``run()`` calls — the
pool is only torn down by ``close()``), crash-detected (an exiting worker
fails its job through the same requeue-once-then-fail policy), and
replaced lazily while work remains.  Each worker holds a read-only view of the score
cache (``ScoreCacheReader`` on the WAL DB), so groups another sweep
process scored mid-run are served without compiling.

Everything crosses the process boundary as the JSON wire format of
``backends.base`` (JobSpec / JobOutcome + arch/shape registry specs) —
exactly what a remote/HTTP backend will speak next.
"""
from __future__ import annotations

import logging
import multiprocessing as mp
import multiprocessing.connection
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.core.backends.base import (FAILED, PRUNED, DONE, IncumbentTracker,
                                      JobOutcome, JobSpec, RetryPolicy,
                                      ScoringBackend, executor_from_spec,
                                      executor_to_spec)

log = logging.getLogger("repro.backends.process")

_POLL_S = 0.05          # parent event-loop tick
_SPAWN_TIMEOUT_S = 120  # budget for a worker to import jax and report ready

#: set once the forkserver has been asked to preload jax (the request is
#: sticky for the life of the forkserver process, so asking again is
#: pointless — and ignored by the stdlib once the server is running)
_FORKSERVER_PRELOADED = False


def _resolve_ctx(start_method: str):
    """Resolve a start-method name to a multiprocessing context.

    ``"auto"`` prefers **forkserver** with jax preloaded into the server
    process: the stdlib forkserver imports the preload list once, and
    every worker then *forks* from that warm interpreter — spawning a
    worker costs a fork plus executor construction instead of a cold
    multi-second jax import.  (Preloading only imports jax; backends
    initialize lazily in each worker, so the fork never clones live
    device state.)  Platforms without forkserver fall back to plain
    ``"spawn"``.  Explicit method names pass through unchanged, so
    ``start_method="spawn"`` still means spawn.
    """
    global _FORKSERVER_PRELOADED
    if start_method != "auto":
        return mp.get_context(start_method)
    if "forkserver" not in mp.get_all_start_methods():
        return mp.get_context("spawn")
    ctx = mp.get_context("forkserver")
    if not _FORKSERVER_PRELOADED:
        try:
            ctx.set_forkserver_preload(["jax"])
            _FORKSERVER_PRELOADED = True
        except Exception as e:     # pragma: no cover - stdlib quirk
            log.debug("forkserver preload unavailable: %s", e)
    return ctx


# --- worker side -------------------------------------------------------------

def _score_one(executor, cfg, shape, spec: JobSpec, cache, shape_key: str,
               mesh_key: str) -> JobOutcome:
    from repro.core.executor import CombinationFailed
    # a mesh-axis job carries its own cache environment column; the init
    # message's mesh_key covers fixed-mesh/local jobs
    env = spec.mesh_key or mesh_key
    if cache is not None and spec.signature:
        hit = cache.get(spec.signature, shape_key, env, spec.eff_cid)
        if hit is not None and hit["status"] in (DONE, FAILED):
            return JobOutcome(spec.key, hit["status"], cost=hit["cost"],
                              error=hit["error"], cached=True)
    kw = {}
    if spec.mesh is not None:
        # the swept topology point: THIS worker materializes the spec
        # against its own local devices (memoized across its jobs)
        from repro.core.meshspec import MeshUnsatisfiable, cached_mesh
        try:
            kw["mesh"] = cached_mesh(spec.mesh)
        except MeshUnsatisfiable as e:
            # environment-dependent (another host may have the devices):
            # transient, so it is retryable and never cached
            return JobOutcome(spec.key, FAILED, error=str(e), transient=True,
                              kind="mesh")
    try:
        cost = executor.score_segment(cfg, shape, spec.seg, spec.combo,
                                      knobs=spec.knobs, **kw)
    except CombinationFailed as e:
        transient = getattr(e, "transient", False)
        return JobOutcome(spec.key, FAILED, error=str(e),
                          transient=transient,
                          kind="deadline" if transient else "")
    except Exception as e:
        # an analysis bug must fail the row, not kill the worker
        return JobOutcome(spec.key, FAILED,
                          error=f"{type(e).__name__}: {e}")
    return JobOutcome(spec.key, DONE, cost=cost.as_dict())


def _worker_main(conn, init: Dict):
    """Worker process entry point: build cfg/shape/executor once (warm
    reuse), then serve JobSpec JSON until a ``None`` shutdown message."""
    from repro.configs.registry import arch_from_spec, shape_from_spec
    from repro.core.db import ScoreCacheReader
    cfg = arch_from_spec(init["arch"])
    shape = shape_from_spec(init["shape"])
    # allow_test: a local worker trusts its parent process (the
    # fault-injection executors exist for the backend's own tests)
    executor = executor_from_spec(init["executor"], allow_test=True)
    cache = ScoreCacheReader(init["db_path"]) if init.get("db_path") else None
    conn.send({"ready": True})
    try:
        while True:
            msg = conn.recv()
            if msg is None:
                return
            spec = JobSpec.from_json(msg)
            out = _score_one(executor, cfg, shape, spec, cache,
                             init.get("shape_key", ""),
                             init.get("mesh_key", ""))
            conn.send(out.to_json())
    except (EOFError, KeyboardInterrupt):
        return
    finally:
        if cache is not None:
            cache.close()


# --- parent side -------------------------------------------------------------

class _Worker:
    __slots__ = ("proc", "conn", "job", "started", "spawned", "ready", "wid")

    def __init__(self, proc, conn, wid: int):
        self.proc = proc
        self.conn = conn
        self.wid = wid
        self.job: Optional[JobSpec] = None
        self.started: float = 0.0
        self.spawned: float = time.monotonic()
        self.ready = False


class ProcessBackend(ScoringBackend):
    """Score jobs on a pool of spawned worker processes with hard
    preemptive per-job timeouts and requeue-once-then-fail recovery."""

    name = "process"
    #: dispatches per job before a loss becomes a transient failure
    max_attempts = 2
    #: parent kills at timeout_s * (1 + grace): the worker's in-process
    #: SIGALRM fires at timeout_s and reports gracefully (keeping the
    #: worker warm); the parent kill is the backstop for native hangs
    kill_grace = 0.2

    def __init__(self, executor, cfg, shape, *, workers: int = 2,
                 prune: bool = False, prune_margin: float = 0.1,
                 timeout_s: Optional[float] = None,
                 db_path: Optional[str] = None,
                 shape_key: str = "", mesh_key: str = "",
                 start_method: str = "auto",
                 retry: Optional[RetryPolicy] = None,
                 fault_plan=None):
        from repro.configs.registry import arch_to_spec, shape_to_spec
        self.workers = max(1, int(workers))
        self.timeout_s = timeout_s
        # the unified retry contract: how many dispatches a job gets
        # before a loss becomes a transient failure
        if retry is not None:
            self.max_attempts = max(1, retry.max_attempts)
        #: FaultPlan consulted at "process.kill_worker" after each
        #: dispatch (tests only; None in production = one branch per job)
        self.fault_plan = fault_plan
        self.prune = prune
        self.prune_margin = prune_margin
        self.tracker = IncumbentTracker(prune, prune_margin)
        self._ctx = _resolve_ctx(start_method)
        self._pool: List[_Worker] = []
        self._next_wid = 0
        self._deaths = 0            # workers lost (crash or kill)
        #: (job key, worker id) per successful dispatch of the last run —
        #: the observable record of the requeue-diversification policy
        self.dispatch_log: List[Tuple[str, int]] = []
        self._init = {
            "executor": executor_to_spec(executor),
            "arch": arch_to_spec(cfg),
            "shape": shape_to_spec(shape),
            "db_path": db_path if db_path and db_path != ":memory:" else None,
            "shape_key": shape_key,
            "mesh_key": mesh_key,
        }

    # ------------------------------------------------------------------
    def _spawn(self) -> _Worker:
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(target=_worker_main,
                                 args=(child, self._init), daemon=True)
        proc.start()
        child.close()
        w = _Worker(proc, parent, self._next_wid)
        self._next_wid += 1
        self._pool.append(w)
        return w

    def _kill(self, w: _Worker):
        if w in self._pool:
            self._pool.remove(w)
        try:
            w.proc.terminate()
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(timeout=5)
        finally:
            try:
                w.conn.close()
            except OSError:
                pass
        self._deaths += 1

    def warmup(self, timeout_s: float = _SPAWN_TIMEOUT_S):
        """Spawn the full pool and block until every worker reports
        ready (jax imported, executor built).  Optional — ``run`` spawns
        lazily — but lets callers keep worker start-up out of timing
        windows."""
        while len(self._pool) < self.workers:
            self._spawn()
        t0 = time.monotonic()
        while any(not w.ready for w in self._pool):
            if time.monotonic() - t0 > timeout_s:
                self.close()        # don't leak the healthy workers
                raise RuntimeError("process-backend worker failed to start "
                                   f"within {timeout_s}s")
            for w in list(self._pool):
                if not w.ready and not w.proc.is_alive():
                    wid, code = w.wid, w.proc.exitcode
                    self.close()
                    raise RuntimeError(
                        f"worker {wid} died during startup (exit {code})")
            self._drain_messages(block_s=_POLL_S)

    # ------------------------------------------------------------------
    def _drain_messages(self, block_s: float = _POLL_S) -> List[JobOutcome]:
        """Receive ready-pings and outcomes from every live worker."""
        outcomes: List[JobOutcome] = []
        conns = {w.conn: w for w in self._pool}
        if not conns:
            time.sleep(block_s)
            return outcomes
        for conn in mp.connection.wait(list(conns), timeout=block_s):
            w = conns[conn]
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                continue        # death handled by the liveness check
            if isinstance(msg, dict) and msg.get("ready"):
                w.ready = True
                continue
            out = JobOutcome.from_json(msg)
            if out.status == DONE and out.cost and w.job is not None:
                from repro.core.cost_model import CostTerms
                self.tracker.observe(w.job.segments,
                                     CostTerms.from_dict(out.cost).total_s)
            w.job = None
            outcomes.append(out)
        return outcomes

    def _lose(self, w: _Worker, reason: str, queue, attempts, excluded,
              kind: str = "crash") -> Optional[JobOutcome]:
        """A busy worker died or was killed: requeue its job until the
        retry policy's ``max_attempts`` is burned, then fail it as
        transient.  The lost worker's id joins the job's excluded set so
        the retry is never dispatched back to it (or to whatever
        inherits its id) — the retry must diversify, not burn itself on
        the same slot that just died."""
        job = w.job
        self._kill(w)
        excluded.setdefault(job.key, set()).add(w.wid)
        attempts[job.key] = attempts.get(job.key, 0) + 1
        if attempts[job.key] >= self.max_attempts:
            log.warning("job %s lost %d times (%s): transient failure",
                        job.key, attempts[job.key], reason)
            return JobOutcome(job.key, FAILED, error=f"{reason}; requeue "
                              "limit reached", transient=True,
                              attempts=attempts[job.key], kind=kind)
        log.warning("job %s lost (%s): requeued", job.key, reason)
        queue.appendleft(job)
        return None

    def _next_job(self, w: _Worker, queue, excluded: Dict[str, Set[int]],
                  attempts: Dict[str, int]
                  ) -> Tuple[Optional[JobSpec], List[JobOutcome]]:
        """Pop the first job dispatchable to ``w``: pruned jobs are
        settled on the spot (returned for yielding), jobs excluded on
        ``w`` — they already died in its hands once — stay queued for a
        different worker."""
        pruned: List[JobOutcome] = []
        skipped: List[JobSpec] = []
        job = None
        while queue:
            j = queue.popleft()
            if self.tracker.pruned(j):
                pruned.append(JobOutcome(
                    j.key, PRUNED,
                    error=f"lower bound {j.bound_s:.3e}s > incumbent best",
                    attempts=attempts.get(j.key, 0) + 1))
                continue
            if w.wid in excluded.get(j.key, ()):
                skipped.append(j)
                continue
            job = j
            break
        for j in reversed(skipped):
            queue.appendleft(j)
        return job, pruned

    def _dispatch(self, w: _Worker, job: JobSpec, queue) -> bool:
        """Send ``job`` to ``w``; on a dead pipe the job goes back to the
        queue attempt-free (it never started) and the worker is culled."""
        try:
            w.conn.send(job.to_json())
        except (OSError, ValueError):
            queue.appendleft(job)
            self._kill(w)
            return False
        w.job = job
        w.started = time.monotonic()
        self.dispatch_log.append((job.key, w.wid))
        if self.fault_plan is not None and \
                self.fault_plan.fires("process.kill_worker") is not None:
            # chaos: the worker dies holding the job it just accepted —
            # the liveness check sees the crash and requeues per policy
            log.warning("fault injection: killing worker %d holding %s",
                        w.wid, job.key)
            w.proc.terminate()
        return True

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[JobSpec],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobOutcome]:
        """Score ``jobs``; the worker pool survives the call.

        Successive ``run()`` calls on one backend reuse the warm workers
        (jax already imported, executor built) — that is what keeps the
        outer knob axis, and repeated sweeps through a cached tuner
        engine, from paying the ~seconds-per-worker spawn cost per call.
        Incumbents do NOT carry over: each run gets a fresh tracker
        seeded only from its own ``incumbents``, so a previous sweep's
        bests can never prune this one's rows.
        """
        # engine-reuse hygiene: a previous run that ended in an error or
        # an abandoned generator can leave dead workers in the pool —
        # cull them before they can swallow this run's dispatches
        for w in list(self._pool):
            if not w.proc.is_alive():
                self._kill(w)
        self.tracker = IncumbentTracker(self.prune, self.prune_margin)
        self.tracker.seed(incumbents)
        self._deaths = 0
        self.dispatch_log = []
        queue = deque(jobs)
        attempts: Dict[str, int] = {}
        excluded: Dict[str, Set[int]] = {}
        death_budget = 2 * self.workers + self.max_attempts * len(queue) + 4
        try:
            while queue or any(w.job is not None for w in self._pool):
                # keep the pool at strength while work remains
                busy = sum(1 for w in self._pool if w.job is not None)
                need = min(self.workers, busy + len(queue))
                while len(self._pool) < need:
                    self._spawn()

                # dispatch to ready idle workers, oldest-spawned first
                # (pruning at dispatch time, same as the thread runner's
                # job-start check).  A requeued job skips workers in its
                # excluded set — the retry prefers a proven survivor
                # over the worker (or slot) it just died on.
                idle = [w for w in self._pool if w.job is None and w.ready]
                idle.sort(key=lambda w: (w.spawned, w.wid))
                dispatched = False
                for w in idle:
                    job, pruned_outs = self._next_job(w, queue, excluded,
                                                      attempts)
                    for out in pruned_outs:
                        yield out
                    if job is None:
                        continue
                    if self._dispatch(w, job, queue):
                        dispatched = True
                if (queue and not dispatched
                        and not any(w.job is not None for w in self._pool)
                        and any(w.job is None and w.ready and w in self._pool
                                for w in idle)):
                    # every idle worker is excluded for every queued job
                    # and nothing is in flight.  Under the kill-on-loss
                    # policy excluded ids are always dead, so this can't
                    # trigger — but exclusion must degrade to a dispatch,
                    # never to a stalled sweep.
                    w = next(w for w in idle
                             if w.job is None and w.ready and w in self._pool)
                    self._dispatch(w, queue.popleft(), queue)

                for out in self._drain_messages():
                    out.attempts = attempts.get(out.key, 0) + 1
                    yield out

                now = time.monotonic()
                kill_after = self.timeout_s * (1.0 + self.kill_grace) \
                    if self.timeout_s else None
                for w in list(self._pool):
                    if w.job is None:
                        if not w.proc.is_alive():
                            self._kill(w)       # idle death: just cull
                        elif not w.ready and \
                                now - w.spawned > _SPAWN_TIMEOUT_S:
                            # hung during init (never sent ready): the
                            # startup path is covered by the no-hang
                            # guarantee too
                            log.warning("worker %d hung during startup; "
                                        "killed", w.wid)
                            self._kill(w)
                        continue
                    if kill_after and now - w.started > kill_after:
                        out = self._lose(
                            w, f"hard deadline {self.timeout_s}s exceeded "
                               f"(worker {w.wid} killed)", queue, attempts,
                            excluded, kind="deadline")
                        if out is not None:
                            yield out
                    elif not w.proc.is_alive():
                        out = self._lose(
                            w, f"worker {w.wid} crashed "
                               f"(exit {w.proc.exitcode})", queue, attempts,
                            excluded, kind="crash")
                        if out is not None:
                            yield out
                if self._deaths > death_budget:
                    raise RuntimeError(
                        f"process backend lost {self._deaths} workers; "
                        "giving up instead of respawning forever")
        finally:
            # keep the pool warm for the next run(); but if the caller
            # abandoned the generator mid-run (break / error), workers
            # still holding jobs would poison the next call — cull them
            for w in [w for w in self._pool if w.job is not None]:
                self._kill(w)

    # ------------------------------------------------------------------
    def close(self):
        for w in list(self._pool):
            try:
                if w.ready and w.job is None and w.proc.is_alive():
                    w.conn.send(None)           # graceful shutdown
            except (OSError, BrokenPipeError):
                pass
        deadline = time.monotonic() + 2.0
        for w in list(self._pool):
            w.proc.join(timeout=max(0.0, deadline - time.monotonic()))
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2)
            try:
                w.conn.close()
            except OSError:
                pass
        self._pool = []
