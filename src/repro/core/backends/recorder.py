"""Recorder: the sweep pipeline's batched result sink.

Fans one JobOutcome back out to every member (segment, cid) row of its
group, keeps the SweepReport accounting, applies the cache policy, and
writes in batched transactions (``record_many`` / ``cache_put_many``) on
the WAL connection instead of one commit per row.

Cache policy — decided by the *outcome*, not by error-string matching:
``pruned`` outcomes are project-relative (they depend on the incumbent)
and never cached; ``transient`` failures (deadline overruns, worker
crashes) depend on machine load / the time budget and never cached — a
bigger budget must be able to retry them.  Deterministic results (done,
lowering/sharding failures) are cached.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.backends.base import DONE, FAILED, PRUNED, JobGroup, JobOutcome
from repro.core.db import SweepDB


class Recorder:
    def __init__(self, db: SweepDB, project: str, report, *,
                 shape_key: str = "", mesh_key: str = "",
                 use_cache: bool = True, batch: int = 64,
                 fault_plan=None):
        self.db = db
        self.project = project
        self.report = report
        self.shape_key = shape_key
        self.mesh_key = mesh_key
        self.use_cache = use_cache
        self.batch = max(1, int(batch))
        #: FaultPlan consulted at "recorder.flush" (tests only)
        self.fault_plan = fault_plan
        self._rows: List[Dict] = []
        self._cache: List[Dict] = []

    # ------------------------------------------------------------------
    def invalid(self, segment: str, cid: str, msg: str):
        self._rows.append({"segment": segment, "cid": cid,
                           "status": "invalid", "error": msg})
        self._maybe_flush()

    def static(self, segment: str, cid: str, diags):
        """Settle a row rejected by the static analyzer (strict mode).

        A ``static`` row never touches ``self._cache``: the rejection is
        a *pre-dispatch* verdict of this engine's rule set, not a scored
        outcome — caching it would let a later (possibly fixed) rule set
        serve a stale rejection as if a compile had failed."""
        msg = "; ".join(f"{d.rule}: {d.message}" for d in diags)
        self._rows.append({"segment": segment, "cid": cid,
                           "status": "static", "error": msg})
        self._maybe_flush()

    def static_note(self, diags):
        """Account one row's diagnostics in the per-rule histogram
        (``SweepReport.static_rules``) — once per row per distinct rule,
        in every mode that lints (strict AND warn)."""
        hist = getattr(self.report, "static_rules", None)
        if hist is None:
            return
        for rule in sorted({d.rule for d in diags}):
            hist[rule] = hist.get(rule, 0) + 1

    def cache_hit(self, group: JobGroup, hit: Dict):
        """Settle a whole group from a persistent-cache entry."""
        self.report.n_cached += len(group.members)
        for sname, cid in group.members:
            self._rows.append({"segment": sname, "cid": cid,
                               "status": hit["status"], "cost": hit["cost"],
                               "error": hit["error"]})
        self._maybe_flush()

    def outcome(self, group: JobGroup, out: JobOutcome):
        """Fan a backend outcome out to all member rows + account it."""
        for sname, cid in group.members:
            self._rows.append({"segment": sname, "cid": cid,
                               "status": out.status, "cost": out.cost,
                               "error": out.error})
        rep = self.report
        # degraded-mode accounting (SweepReport): retries that happened
        # anywhere in the pipeline (requeue, scheduler rounds, fallback
        # handoff) and jobs a local backend picked up after the remote
        # budget ran out — a degraded run must report itself loudly
        rep.n_transient_retried += max(0, out.attempts - 1)
        if out.fallback:
            rep.n_fallback_local += len(group.members)
        if out.status == FAILED:
            kind = out.kind or \
                ("transient" if out.transient else "deterministic")
            rep.failure_kinds[kind] = \
                rep.failure_kinds.get(kind, 0) + len(group.members)
        if out.status == PRUNED:
            rep.n_pruned += len(group.members)
        elif out.cached:
            # a worker served this group from the shared score cache —
            # no compile happened, so it counts as cached, not scored
            rep.n_cached += len(group.members)
        else:
            if out.status == DONE:
                rep.n_scored += 1
                rep.n_shared += len(group.members) - 1
            elif out.status == FAILED and out.transient:
                rep.n_transient += len(group.members)
            if self.use_cache and not out.transient:
                # a mesh-axis group banks under ITS point's environment
                # column (set by the Scheduler), not the pipeline default
                self._cache.append(
                    {"signature": group.signature, "shape": self.shape_key,
                     "mesh": group.mesh_key or self.mesh_key,
                     "cid": group.eff_cid,
                     "status": out.status, "cost": out.cost,
                     "error": out.error})
        self._maybe_flush()

    # ------------------------------------------------------------------
    def _maybe_flush(self):
        if len(self._rows) >= self.batch:
            self.flush()

    def flush(self):
        if self.fault_plan is not None and \
                self.fault_plan.fires("recorder.flush") is not None:
            raise RuntimeError("fault injection: recorder flush crashed")
        if self._rows:
            self.db.record_many(self.project, self._rows)
            self._rows = []
        if self._cache:
            self.db.cache_put_many(self._cache)
            self._cache = []
