"""Remote scoring backend: ship JobSpecs to a sweep scoring server.

The client half of sweep-as-a-service (``backends/server.py``): jobs
leave this host as the JSON wire format of ``backends.base`` and come
back as :class:`JobOutcome` streams over a long-poll cursor.  The
Scheduler and Recorder stages are untouched — this backend slots into
``ComParTuner.sweep(backend="remote", remote_url=...)`` exactly where
the thread/process backends do.

Failure contract (the part that keeps the cache honest):

* **Idempotent retries.**  Jobs are content-keyed — the server derives
  the batch id from the payload's sha1 — so a submit replayed after a
  connection loss *attaches* to the original batch, and the outcome
  cursor (``after=N``) makes polls replay-safe.  A batch the server no
  longer knows (it restarted) is simply resubmitted: every score it
  already banked is served back from its persistent cache.
* **Unreachable server = transient.**  If the server stays unreachable
  past the retry budget, every unfinished job fails with
  ``transient=True`` — the Recorder never caches transient outcomes, so
  an outage can never be poisoned into ``score_cache`` as if the
  combinations themselves were bad.  A later sweep retries them.
* **Protocol errors raise.**  HTTP 4xx (wire-version mismatch, rejected
  executor spec, bad/missing auth token) is a bug, not an outage —
  retrying can never succeed, so the sweep fails loudly instead.
  5xx, torn replies (truncated/corrupt JSON), and transport losses are
  the server's problem, not the client's: all retried within the
  :class:`~repro.core.backends.base.RetryPolicy` budget with jittered
  exponential backoff (no thundering herd after a restart).

Pruning runs client-side at submit time against the seeded incumbents
(the server is incumbent-free: incumbents are a property of the client's
project, not of the shared score pool).
"""
from __future__ import annotations

import http.client
import json
import logging
import time
import urllib.error
import urllib.request
import uuid
from typing import Dict, Iterator, Optional, Sequence

from repro.core.backends.base import (FAILED, PRUNED, WIRE_VERSION,
                                      IncumbentTracker, JobOutcome, JobSpec,
                                      RetryPolicy, ScoringBackend,
                                      executor_to_spec)

log = logging.getLogger("repro.backends.remote")

#: sentinel `_request` returns for a recoverable HTTP 404 (unknown batch)
_NOT_FOUND = {"_not_found": True}


class RemoteBackend(ScoringBackend):
    """Score jobs on a remote sweep scoring server over HTTP."""

    name = "remote"

    def __init__(self, executor, cfg, shape, *, url: str,
                 prune: bool = False, prune_margin: float = 0.1,
                 timeout_s: Optional[float] = None,
                 shape_key: str = "", mesh_key: str = "",
                 poll_s: float = 20.0, retry_s: Optional[float] = None,
                 backoff_s: Optional[float] = None,
                 retry: Optional[RetryPolicy] = None,
                 token: Optional[str] = None):
        from repro.configs.registry import arch_to_spec, shape_to_spec
        self.url = url.rstrip("/")
        self.prune = prune
        self.prune_margin = prune_margin
        self.tracker = IncumbentTracker(prune, prune_margin)
        self.poll_s = poll_s        # long-poll window per outcomes request
        # retry_s/backoff_s predate RetryPolicy; they overlay the policy
        # so existing call sites keep their behavior
        base = retry if retry is not None else RetryPolicy()
        if retry_s is not None or backoff_s is not None:
            import dataclasses
            base = dataclasses.replace(
                base,
                budget_s=base.budget_s if retry_s is None else retry_s,
                base_s=base.base_s if backoff_s is None else backoff_s)
        self.retry = base
        self.retry_s = self.retry.budget_s
        self.token = token
        # a fixed-mesh executor ships its mesh as a declarative MeshSpec
        # (executor_to_spec); the server materializes it against its own
        # devices — or rejects the submit with HTTP 400 if it can't
        self._init = {
            "executor": executor_to_spec(executor),
            "arch": arch_to_spec(cfg),
            "shape": shape_to_spec(shape),
            "shape_key": shape_key,
            "mesh_key": mesh_key,
        }

    # ------------------------------------------------------------------
    def _request(self, path: str, payload: Optional[Dict] = None,
                 timeout: Optional[float] = None) -> Optional[Dict]:
        """One HTTP exchange with idempotent transient-failure retries.

        Returns the decoded JSON reply; ``_NOT_FOUND`` for a recoverable
        404; ``None`` once the server stayed unavailable past the retry
        budget.  Retryable: transport losses (connection refused/reset,
        timeouts), torn replies (truncated or corrupt JSON — the server
        or a proxy died mid-write), and HTTP 5xx (the server or a proxy
        in front of a restarting server failed the request).  Backoff is
        jittered exponential per :class:`RetryPolicy` so a fleet of
        clients recovering from one restart doesn't re-poll in lockstep.
        Other HTTP errors raise — they are protocol bugs a retry cannot
        fix; 401 in particular is never retried (a wrong token stays
        wrong)."""
        data = json.dumps(payload).encode() if payload is not None else None
        headers = {"Content-Type": "application/json"}
        if self.token:
            headers["Authorization"] = f"Bearer {self.token}"
        deadline = time.monotonic() + self.retry.budget_s
        attempt = 0
        while True:
            req = urllib.request.Request(self.url + path, data=data,
                                         headers=headers)
            try:
                with urllib.request.urlopen(req, timeout=timeout) as resp:
                    return json.loads(resp.read().decode())
            except urllib.error.HTTPError as e:
                if e.code == 404:
                    return _NOT_FOUND
                if e.code < 500:
                    body = e.read().decode(errors="replace")
                    hint = " (wrong or missing --token? pass " \
                        "remote_token=/token=)" if e.code == 401 else ""
                    raise RuntimeError(
                        f"scoring server rejected {path}: "
                        f"HTTP {e.code}{hint} {body}") from e
                err: Exception = e      # 5xx: retryable server failure
            except (urllib.error.URLError, ConnectionError, OSError,
                    TimeoutError, http.client.HTTPException,
                    json.JSONDecodeError, UnicodeDecodeError) as e:
                # HTTPException covers torn replies (IncompleteRead,
                # BadStatusLine); JSON/Unicode decode failures are the
                # same event seen one layer up — bytes from a server or
                # proxy that died mid-write
                err = e
            if time.monotonic() >= deadline:
                log.warning("scoring server %s unavailable past %.1fs "
                            "retry budget (%s): %s", self.url,
                            self.retry.budget_s, path, err)
                return None
            time.sleep(self.retry.pause_s(attempt))
            attempt += 1

    def _submit(self, payload: Dict) -> Optional[str]:
        resp = self._request("/v1/submit", payload,
                             timeout=max(self.retry_s, 10.0))
        if resp is _NOT_FOUND:
            # only /v1/outcomes 404s (a forgotten batch) are recoverable;
            # a 404 on submit means the URL is not a scoring server —
            # that's a protocol error, not an outage
            raise RuntimeError(
                f"scoring server rejected /v1/submit with HTTP 404 — is "
                f"{self.url} really a sweep scoring server "
                f"(python -m repro.core.backends.server)?")
        if resp is None:
            return None
        return resp["batch"]

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[JobSpec],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobOutcome]:
        self.tracker = IncumbentTracker(self.prune, self.prune_margin)
        self.tracker.seed(incumbents)
        submit = []
        for job in jobs:
            if self.tracker.pruned(job):
                yield JobOutcome(job.key, PRUNED,
                                 error=f"lower bound {job.bound_s:.3e}s > "
                                       "incumbent best")
            else:
                submit.append(job)
        if not submit:
            return
        # the run nonce scopes batch idempotency to THIS run(): retries
        # and resubmits replay the same payload (same batch), while a
        # *different* sweep with identical jobs gets a fresh batch whose
        # scores resolve from the server's cache as cached=True — so a
        # client's n_scored counts only compiles done on its behalf
        payload = {"v": WIRE_VERSION, "run": uuid.uuid4().hex,
                   "init": self._init,
                   "jobs": [j.to_json() for j in submit]}
        pending = {j.key for j in submit}

        def fail_pending(reason: str,
                         kind: str = "unreachable") -> Iterator[JobOutcome]:
            # server-side losses are never a verdict on the combination:
            # transient means the Recorder won't cache them, and a
            # FallbackBackend (or a later sweep / a scheduler retry
            # round) re-scores them
            for key in sorted(pending):
                yield JobOutcome(key, FAILED, error=reason, transient=True,
                                 kind=kind)

        batch = self._submit(payload)
        if batch is None:
            yield from fail_pending(
                f"scoring server {self.url} unreachable (submit)")
            return
        after = 0
        while pending:
            resp = self._request(
                f"/v1/outcomes?batch={batch}&after={after}"
                f"&wait={self.poll_s:g}", timeout=self.poll_s + 30.0)
            if resp is None:
                yield from fail_pending(
                    f"scoring server {self.url} unreachable (poll)")
                return
            if resp is _NOT_FOUND:
                # the server forgot the batch (restart/eviction): the
                # payload is content-keyed, so resubmitting resumes it —
                # already-banked scores come back as cache hits
                log.warning("batch %s unknown to %s: resubmitting",
                            batch, self.url)
                batch = self._submit(payload)
                if batch is None:
                    yield from fail_pending(
                        f"scoring server {self.url} unreachable (resubmit)")
                    return
                after = 0
                continue
            for od in resp.get("outcomes", []):
                after += 1
                out = JobOutcome.from_json(od)
                if out.key not in pending:
                    continue            # replayed duplicate after a resubmit
                pending.discard(out.key)
                yield out
            if resp.get("done") and pending:
                err = resp.get("error") or \
                    "server finished without scoring all jobs"
                yield from fail_pending(f"scoring server error: {err}",
                                        kind="server")
                return

    def close(self):
        """Stateless client: nothing to release (pools live server-side)."""
