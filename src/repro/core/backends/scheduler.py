"""Scheduler: registered rows -> ordered unique JobSpecs.

The stage of the sweep pipeline that runs before any scoring: structural
grouping (rows that build the same program share one job), black-box
validation, persistent score-cache resolution (whole groups settled
without compiling), and lower-bound ordering (cheapest analytic bound
first, so incumbents tighten early and pruning bites sooner).
Extracted from the monolithic ``ComParTuner._execute``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.backends.base import FAILED, JobGroup, JobSpec
from repro.core.backends.recorder import Recorder
from repro.core.combinator import (Combination, GlobalKnobs, effective_cid,
                                   mapping_key, row_cid)
from repro.core.cost_model import CostTerms, V5E, combo_lower_bound
from repro.core.db import SweepDB
from repro.core.fusion import max_boundary_cost_s
from repro.core.meshspec import MeshSpec
from repro.core.segment import Segment
from repro.core.validator import validate_combination

#: statuses that Continue mode treats as settled (no re-run on resume)
SETTLED = ("done", "failed", "invalid", "pruned", "static")


def shape_key(shape: ShapeConfig) -> str:
    return f"{shape.kind}:{shape.seq_len}x{shape.global_batch}"


def mesh_key(mesh) -> str:
    """Mesh content key for the ``score_cache.mesh`` column: the
    versioned :attr:`MeshSpec.mid` (``"local"`` for no mesh).  Accepts a
    live ``jax.Mesh``, a :class:`MeshSpec`, or ``None`` — a fixed live
    mesh and a swept spec with the same content produce the SAME key, so
    fixed-mesh and mesh-axis sweeps share cache rows.  The version bump
    (``meshspec.MESH_KEY_VERSION``) means rows written by the pre-spec
    engine can never alias spec-keyed ones."""
    if mesh is None:
        return "local"
    spec = mesh if isinstance(mesh, MeshSpec) else MeshSpec.from_mesh(mesh)
    return spec.mid


def env_key(mesh, executor) -> str:
    """The score-cache environment key: mesh content + the executor's
    ``cache_tag``.  Scores from a different executor or hardware model
    are never interchangeable."""
    return f"{mesh_key(mesh)}/{getattr(executor, 'cache_tag', 'unknown')}"


# aliases usable where Scheduler's parameter names shadow the functions
_shape_key_fn = shape_key
_env_key_fn = env_key


@dataclass
class SweepWork:
    """What the Scheduler hands the backend: ordered unique jobs, the
    groups to fan outcomes back out to, and seeded incumbents."""
    jobs: List[JobSpec] = field(default_factory=list)
    groups: Dict[str, JobGroup] = field(default_factory=dict)
    incumbents: Dict[str, float] = field(default_factory=dict)
    shape_key: str = ""
    mesh_key: str = ""


class Scheduler:
    def __init__(self, db: SweepDB, project: str, cfg: ArchConfig,
                 shape: ShapeConfig, mesh, executor, *,
                 validate: bool = False, share_scores: bool = True,
                 use_cache: bool = True,
                 shape_key: Optional[str] = None,
                 mesh_key: Optional[str] = None,
                 boundary_slack: bool = False,
                 kernel_tuning=None,
                 static_checks: str = "off",
                 static_devices: bool = False):
        self.db = db
        self.project = project
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.executor = executor
        self.validate = validate
        self.share_scores = share_scores
        self.use_cache = use_cache
        # boundary-cost fusion is active: jobs carry the Viterbi pruning
        # allowance (JobSpec.slack_s) so prune=True stays exact under it
        self.boundary_slack = boundary_slack
        # the kernel autotuner's verdict (autotune.KernelTuning): per-
        # schedule certified kernel flops tighten each job's compute
        # floor; None = no kernel axis, bounds unchanged
        self.kernel_tuning = kernel_tuning
        # static lint mode: "off" (hand-built Schedulers: no lint at
        # all), "warn" (lint + histogram, every point still dispatched),
        # "strict" (error-diagnosed rows settled as "static" before they
        # become JobSpecs — sound: every dropped point provably fails)
        if static_checks not in ("strict", "warn", "off"):
            raise ValueError(f"static_checks={static_checks!r}: expected "
                             f"'strict' | 'warn' | 'off'")
        self.static_checks = static_checks
        # host-local mesh satisfiability (MeshSpec.check_local) is only
        # a valid rule when the linting host IS the scoring host — the
        # tuner enables it for every backend except remote
        self.static_devices = static_devices
        # the cache keys the pipeline reads AND writes under — a caller
        # (the tuner) passes one pair so write and read can't desync
        self.shape_key = shape_key if shape_key is not None \
            else _shape_key_fn(shape)
        self.mesh_key = mesh_key if mesh_key is not None \
            else _env_key_fn(mesh, executor)

    # ------------------------------------------------------------------
    def build(self, segs: Sequence[Segment],
              per_seg_combos: Dict[str, List[Combination]],
              recorder: Recorder,
              knob_points: Optional[Sequence[GlobalKnobs]] = None,
              mesh_points: Optional[Sequence[MeshSpec]] = None
              ) -> SweepWork:
        """Group, validate, cache-resolve, bound and order the pending
        rows of every (segment, combination, knob point, mesh point)
        tuple.  Invalid rows and cache hits are settled through the
        recorder; everything else becomes a JobSpec.

        Rows across knob points whose relevant knob projection agrees
        land in the same group (one compile); incumbents — and therefore
        pruning — are scoped per ``"<knob kid>/<segment>"`` so one knob
        point's best never prunes another point's per-segment argmin.

        ``mesh_points`` (``None`` = the mesh is not swept: today's
        single fixed-mesh behavior) adds the topology axis: every point
        gets its own row ids (``row_cid(..., mesh=point)``), its own
        score-cache environment column (``<mid>/<cache_tag>``), its own
        mesh-qualified incumbent scopes (``<mid>/<kid>/<segment>``) and
        its own lower bounds (divided by the *point's* chip count) —
        groups never span mesh points, because two topologies never
        share a compiled program's environment.
        """
        points = list(knob_points) if knob_points else [GlobalKnobs()]
        swept_mesh = mesh_points is not None
        mpoints: List[Optional[MeshSpec]] = \
            list(mesh_points) if swept_mesh else [None]
        work = SweepWork(shape_key=self.shape_key, mesh_key=self.mesh_key)
        statuses = self.db.statuses(self.project)

        # incumbent best per (mesh point, knob point, segment), seeded
        # from prior rows (resume); pre-knob rows carry no knobs = the
        # default point, pre-mesh/fixed-mesh rows carry no mesh = the
        # unqualified scope
        for r in self.db.results(self.project):
            if r["status"] == "done" and r["cost"]:
                t = CostTerms.from_dict(r["cost"]).total_s
                scope = f"{(r['knobs'] or GlobalKnobs()).kid}/{r['segment']}"
                if r["mesh"] is not None:
                    scope = f"{r['mesh'].mid}/{scope}"
                cur = work.incumbents.get(scope)
                if cur is None or t < cur:
                    work.incumbents[scope] = t

        # group pending rows by structural program identity (never
        # across mesh points: the group key carries the point's mid)
        valid_memo: Dict[str, Tuple[bool, str]] = {}
        static_memo: Dict[Tuple, list] = {}
        map_memo: Dict[Tuple[Optional[str], str, str], str] = {}
        # per-segment invariants, computed once (not per mesh/knob point)
        seg_memo = {seg.name: (seg.signature(self.cfg, self.shape),
                               seg.relevant_clause_fields(self.shape.kind),
                               seg.relevant_knob_fields(self.shape.kind))
                    for seg in segs}
        for mp in mpoints:
            mmid = mp.mid if mp is not None else None
            mesh_for_map = mp if swept_mesh else self.mesh
            # ONE encoder for the environment column: env_key accepts a
            # MeshSpec, so swept and fixed-mesh sweeps can never drift
            # into differently-formatted (cache-splitting) keys
            env = _env_key_fn(mp, self.executor) if swept_mesh \
                else self.mesh_key
            for kn in points:
                gid = kn.kid
                for seg in segs:
                    sig, relevant, rel_knobs = seg_memo[seg.name]
                    for c in per_seg_combos[seg.name]:
                        rid = row_cid(c, kn, mp if swept_mesh else None)
                        if statuses.get((seg.name, rid)) in SETTLED:
                            continue
                        if self.validate:
                            if c.cid not in valid_memo:
                                valid_memo[c.cid] = \
                                    validate_combination(self.cfg, c)
                            ok, msg = valid_memo[c.cid]
                            if not ok:
                                recorder.invalid(seg.name, rid, msg)
                                continue
                        if self.static_checks != "off":
                            # diagnostics depend only on (segment,
                            # combination, knob point, mesh point) — one
                            # lint per distinct tuple, accounted per row
                            skey = (seg.name, c.cid, kn.kid, mmid)
                            diags = static_memo.get(skey)
                            if diags is None:
                                from repro.analysis.rules import \
                                    analyze_point
                                diags = analyze_point(
                                    self.cfg, self.shape, c, knobs=kn,
                                    mesh=mp if swept_mesh else self.mesh,
                                    segments=(seg,),
                                    check_devices=self.static_devices)
                                static_memo[skey] = diags
                            if diags:
                                recorder.static_note(diags)
                                errs = [d for d in diags if d.is_error]
                                if errs and self.static_checks == "strict":
                                    recorder.static(seg.name, rid, errs)
                                    continue
                        mk = map_memo.get((mmid, seg.name, c.cid))
                        if mk is None:
                            mk = mapping_key(self.cfg, mesh_for_map, c, seg)
                            map_memo[(mmid, seg.name, c.cid)] = mk
                        ec = effective_cid(c, relevant, mk, kn, rel_knobs)
                        key = f"{sig}/{ec}" if self.share_scores \
                            else f"{seg.name}/{rid}"
                        if swept_mesh:
                            key = f"{mmid}/{key}"
                        g = work.groups.setdefault(
                            key, JobGroup(seg, c, sig, ec, knobs=kn,
                                          mesh=mp if swept_mesh else None,
                                          mesh_key=env if swept_mesh
                                          else ""))
                        g.members.append((seg.name, rid))
                        scope = f"{gid}/{seg.name}"
                        g.scopes.add(f"{mmid}/{scope}" if swept_mesh
                                     else scope)

        # persistent cache stage: resolve whole groups without compiling
        fixed_chips = getattr(self.executor, "n_chips", 1)
        hw = getattr(self.executor, "hw", V5E)
        fixed_axes = dict(zip(self.mesh.axis_names,
                              self.mesh.devices.shape)) \
            if self.mesh is not None else None
        slack_memo: Dict[int, float] = {}
        for key, g in list(work.groups.items()):
            env = g.mesh_key or work.mesh_key
            hit = self.db.cache_get(g.signature, work.shape_key,
                                    env, g.eff_cid) \
                if self.use_cache else None
            if hit is not None:
                recorder.cache_hit(g, hit)
                if hit["status"] == "done" and hit["cost"]:
                    t = CostTerms.from_dict(hit["cost"]).total_s
                    for scope in g.scopes:
                        if t < work.incumbents.get(scope, float("inf")):
                            work.incumbents[scope] = t
                del work.groups[key]
                continue
            n_chips = g.mesh.n_devices if g.mesh is not None else fixed_chips
            mesh_axes = g.mesh.axis_sizes() if g.mesh is not None \
                else fixed_axes
            slack = 0.0
            if self.boundary_slack and len(segs) > 1 and n_chips > 1:
                slack = slack_memo.get(n_chips)
                if slack is None:
                    slack = (len(segs) - 1) * max_boundary_cost_s(
                        self.cfg, self.shape, n_chips, hw)
                    slack_memo[n_chips] = slack
            kflops = self.kernel_tuning.floor_flops(
                g.seg.name, g.combo.clause) \
                if self.kernel_tuning is not None else 0.0
            work.jobs.append(JobSpec(
                key, g.seg, g.combo, segments=tuple(sorted(g.scopes)),
                bound_s=combo_lower_bound(self.cfg, self.shape, g.seg,
                                          g.combo, n_chips, hw,
                                          knobs=g.knobs,
                                          mesh_axes=mesh_axes,
                                          kernel_flops=kflops),
                signature=g.signature, eff_cid=g.eff_cid, knobs=g.knobs,
                mesh=g.mesh, mesh_key=g.mesh_key, slack_s=slack))
        recorder.flush()

        # cheapest-bound-first: incumbents tighten early, pruning bites
        work.jobs.sort(key=lambda j: (j.bound_s, j.key))
        return work


def drive(engine, work: SweepWork, recorder: Recorder, *,
          transient_retries: int = 0):
    """Run ``work`` through ``engine``, recording outcomes — with up to
    ``transient_retries`` bounded re-dispatch rounds for transient
    failures before the sweep concludes.

    Before this existed, ``transient=True`` meant "hope someone sweeps
    again": a deadline double-loss or an outage window left FAILED rows
    that only a *later* sweep would retry.  Now the Scheduler level gives
    transients another chance in-sweep: outcomes that fail transiently
    in round N re-enter the engine in round N+1 (same engine, same
    seeded incumbents — a retried job can still be pruned if an earlier
    round tightened its scopes' bests).  Rounds are bounded, so the
    no-hang guarantee is preserved: whatever is still transient after
    the last round is recorded as before.

    Attempt accounting survives rounds: ``out.attempts`` accumulates
    across re-dispatches, so the Recorder's ``n_transient_retried``
    counts every extra dispatch the sweep performed.
    """
    jobs = list(work.jobs)
    by_key = {j.key: j for j in jobs}
    prior: Dict[str, int] = {}
    for round_no in range(max(0, transient_retries) + 1):
        last = round_no == max(0, transient_retries)
        retry: List[JobSpec] = []
        for out in engine.run(jobs, work.incumbents):
            out.attempts += prior.get(out.key, 0)
            if (not last and out.status == FAILED and out.transient
                    and out.key in by_key):
                retry.append(by_key[out.key])
                prior[out.key] = out.attempts
                continue
            group = work.groups.get(out.key)
            if group is not None:
                recorder.outcome(group, out)
        if not retry:
            return
        jobs = retry
