"""Sweep scoring server: the remote end of sweep-as-a-service.

ROADMAP's "remote/HTTP ScoringBackend": a stdlib-only HTTP service that
fronts a warm :class:`ProcessBackend` pool and a WAL ``score_cache``, so
any number of client sweeps — on this host or others — can ship
:class:`JobSpec` batches here instead of compiling locally.  The payoff
is *cross-host score amortization*: every job is resolved against the
server's persistent ``score_cache`` before any worker spawns, so a
combination any client ever scored is served to every later client
without a compile (this is the amortization that makes multi-compiler
search tractable at fleet scale).

    python -m repro.core.backends.server --db /path/scores.db --workers 4

Protocol (all JSON, wire version ``backends.base.WIRE_VERSION``):

``POST /v1/submit``
    ``{"v": 1, "init": {executor/arch/shape specs + shape_key/mesh_key},
    "jobs": [JobSpec...]}`` → ``{"v": 1, "batch": "<id>", "resumed": bool}``.
    The batch id is the sha1 of the payload content — submits are
    **idempotent**: replaying the same payload (a client retrying after
    a connection loss) attaches to the original batch instead of scoring
    everything twice.
``GET /v1/outcomes?batch=ID&after=N&wait=S``
    long-poll: blocks up to ``S`` seconds for outcomes with index >= N,
    returns ``{"v": 1, "outcomes": [JobOutcome...], "done": bool,
    "error": str}``.  The cursor makes polls replay-safe too.
``GET /v1/health`` / ``GET /v1/stats``
    liveness + counters (``n_compiled``, ``n_cache_hits``,
    ``cache_size``, ``n_evicted``) — the benchmark asserts a cache-warm
    sweep leaves ``n_compiled`` untouched.

Completed batches are TTL-evicted (``--batch-ttl-s``, default 1h): the
outcome log of a finished batch only matters until its client drains
it, and the client's resubmit-on-404 path makes eviction safe even for
a client that comes back later — the resubmitted batch resolves from
the score cache.

Auth: ``--token SECRET`` requires ``Authorization: Bearer SECRET`` on
every request (constant-time compare; 401 otherwise — clients treat
that as a protocol error, never retried).  Binding a non-loopback host
without a token is refused outright: an open scoring server is a free
compile farm plus a writable shared score cache for anyone who finds
the port.  (Transport encryption is still TLS-terminating-proxy
territory — the token travels in clear over plain HTTP.)

Client *executor* specs are deserialized with ``allow_test=False`` by
default: accepting ``{"kind": "crash"}`` from the network would hand
every client a kill switch for the worker pool (``--allow-test-executors``
opts in for fault-injection CI).  Batches never run client code — a
JobSpec names registry configs and enum-like clause fields only.
"""
from __future__ import annotations

import argparse
import hashlib
import hmac
import ipaddress
import json
import logging
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple
from urllib.parse import parse_qs, urlparse

from repro.core.backends.base import (DONE, FAILED, WIRE_VERSION,
                                      JobOutcome, JobSpec, WireVersionError,
                                      check_wire_version, executor_from_spec)
from repro.core.backends.process import ProcessBackend
from repro.core.db import SweepDB

log = logging.getLogger("repro.backends.server")


def batch_id(payload: Dict) -> str:
    """Content key of a submit payload: the same submit always resolves
    to the same batch, so replays after a connection loss are safe.  The
    client's ``run`` nonce is part of the key — idempotency is scoped to
    one client ``run()``; a *different* sweep with identical jobs gets
    its own batch (and its scores from the cache, flagged ``cached``)."""
    blob = json.dumps({"run": payload.get("run"),
                       "init": payload.get("init"),
                       "jobs": payload.get("jobs")}, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:20]


class _Batch:
    """One submitted job batch: outcomes accumulate under a condition
    variable so long-polling readers wake as soon as one lands."""

    def __init__(self, bid: str, init: Dict, jobs: List[Dict]):
        self.bid = bid
        self.init = init
        self.jobs = jobs
        self.outcomes: List[Dict] = []
        self.done = False
        self.error = ""
        self.finished_at: Optional[float] = None   # monotonic, for TTL
        self.cond = threading.Condition()

    def push(self, out: Dict):
        with self.cond:
            self.outcomes.append(out)
            self.cond.notify_all()

    def finish(self, error: str = ""):
        with self.cond:
            self.done = True
            self.error = error
            self.finished_at = time.monotonic()
            self.cond.notify_all()

    def read(self, after: int, wait_s: float
             ) -> Tuple[List[Dict], bool, str]:
        deadline = time.monotonic() + max(0.0, wait_s)
        with self.cond:
            while len(self.outcomes) <= after and not self.done:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                self.cond.wait(left)
            return list(self.outcomes[after:]), self.done, self.error


def _is_loopback(host: str) -> bool:
    """True for hosts that only loopback traffic can reach.  Unknown
    names (and the all-interfaces wildcards) count as non-loopback —
    the guard must fail closed."""
    if host in ("localhost", ""):
        return host == "localhost"
    try:
        return ipaddress.ip_address(host).is_loopback
    except ValueError:
        return False


class SweepScoringServer:
    """HTTP front of a warm ProcessBackend pool + a shared score cache."""

    def __init__(self, db_path: str, *, workers: int = 2,
                 host: str = "127.0.0.1", port: int = 0,
                 allow_test: bool = False, poll_cap_s: float = 60.0,
                 token: Optional[str] = None,
                 batch_ttl_s: float = 3600.0,
                 calibrate: bool = False):
        if token is None and not _is_loopback(host):
            raise ValueError(
                f"refusing to bind non-loopback host {host!r} without a "
                "shared-secret token: an open scoring server is a free "
                "compile farm and a writable score cache for anyone who "
                "finds the port — pass --token (and keep TLS termination "
                "in front for non-trusted networks)")
        self.db = SweepDB(db_path)
        self.db_path = db_path
        #: this host's measured MachineProfile (``--calibrate``): loaded
        #: from (or measured into) the server DB's ``machine_cache``, so
        #: every ``machine="auto"`` tuner sharing this DB — including
        #: remote clients pointed at the same file — reuses one profile
        #: instead of re-running microbenchmarks.  Surfaced in
        #: ``/v1/stats`` so clients can see what this host measured.
        self.profile = None
        if calibrate:
            from repro.core.machine import load_or_calibrate
            self.profile = load_or_calibrate(self.db, tiny=True)
            log.info("host profile %s (pid %s)", self.profile.key,
                     self.profile.pid[:12])
        self.workers = max(1, int(workers))
        self.allow_test = allow_test
        self.poll_cap_s = poll_cap_s
        self.token = token
        self.batch_ttl_s = batch_ttl_s
        self._lock = threading.Lock()       # batches/engines/counters
        self._db_lock = threading.Lock()    # one writer connection
        self._batches: Dict[str, _Batch] = {}
        #: engine-config key -> (backend, run lock); ProcessBackend.run is
        #: not re-entrant, so batches sharing an engine serialize on it
        self._engines: Dict[str, Tuple[ProcessBackend, threading.Lock]] = {}
        self.n_compiled = 0                 # jobs actually compiled here
        self.n_cache_hits = 0               # jobs served from score_cache
        self.n_evicted = 0                  # finished batches TTL-swept
        self._httpd = ThreadingHTTPServer((host, port), _make_handler(self))
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> str:
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        log.info("sweep scoring server listening on %s (db=%s, workers=%d)",
                 self.url, self.db_path, self.workers)
        return self.url

    def close(self):
        """Stop serving and release the worker pools; idempotent (and
        safe on a never-started server: shutdown() would block forever
        waiting for a serve_forever loop that never ran)."""
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5)
            self._thread = None
        self._httpd.server_close()
        with self._lock:
            engines, self._engines = self._engines, {}
        for engine, _ in engines.values():
            try:
                engine.close()
            except Exception:
                log.warning("engine close failed", exc_info=True)

    # ------------------------------------------------------------------
    def submit(self, payload: Dict) -> Tuple[str, bool]:
        """Register a batch (idempotent) and start scoring it.  Returns
        ``(batch_id, resumed)``.  Raises ``WireVersionError`` /
        ``TypeError`` / ``ValueError`` on protocol-level bad payloads —
        the handler maps those to HTTP 400 so the client fails loudly
        instead of retrying a request that can never succeed."""
        self._evict()
        check_wire_version(payload)
        init = payload.get("init") or {}
        if not isinstance(payload.get("jobs"), list):
            raise ValueError("payload has no job list")
        # reject un-servable payloads at submit time (protocol errors,
        # not transient outages — a client must fail loudly, not retry
        # forever): test executors are never admitted from the wire
        # unless this server opted in, and arch/shape/job specs that
        # cannot be reconstructed here (registry version skew, malformed
        # wire data) are a 400, not a batch that 'transiently' fails on
        # every resubmit
        from repro.configs.registry import arch_from_spec, shape_from_spec
        executor = executor_from_spec(init["executor"],
                                      allow_test=self.allow_test)
        arch_from_spec(init["arch"])
        shape_from_spec(init["shape"])
        self._check_cache_tag(executor, init.get("mesh_key", ""))
        for jd in payload["jobs"]:
            spec = JobSpec.from_json(jd)
            if spec.mesh is not None:
                # a MeshSpec THIS host cannot materialize is a protocol
                # error (MeshUnsatisfiable -> HTTP 400): retrying the
                # batch can never succeed here, and a 'transient' verdict
                # would make clients retry it forever
                spec.mesh.check_local()
            self._check_cache_tag(executor, spec.mesh_key)
        bid = batch_id(payload)
        with self._lock:
            batch = self._batches.get(bid)
            resumed = batch is not None
            if not resumed:
                batch = _Batch(bid, init, payload["jobs"])
                self._batches[bid] = batch
        if not resumed:
            threading.Thread(target=self._run_batch, args=(batch,),
                             daemon=True).start()
        return bid, resumed

    @staticmethod
    def _check_cache_tag(executor, mesh_key: str):
        """A client-derived environment column whose executor tag half
        does not match the tag of the executor THIS server rebuilt is a
        protocol error: scores would be measured here but banked under
        the client's environment — e.g. a CPU client's
        ``wallclock:r5:cpu`` column filled with this host's GPU medians,
        served back to genuinely-CPU hosts later.  Only env-formatted
        keys (``<mesh>/<tag>``) are checked; opaque test keys pass."""
        tag = getattr(executor, "cache_tag", None)
        if tag is None or "/" not in mesh_key:
            return
        got = mesh_key.split("/", 1)[1]
        if got != tag:
            raise ValueError(
                f"cache environment tag mismatch: client banked under "
                f"{got!r} but this server's executor scores as {tag!r} — "
                "scores measured here must not be cached as the client's "
                "environment")

    def _evict(self):
        """TTL-sweep finished batches.  Safe by construction: an evicted
        batch polls as 404 and the client resubmits its content-keyed
        payload, which resolves from the score cache.  Caller must NOT
        hold ``_lock``."""
        if self.batch_ttl_s is None or self.batch_ttl_s < 0:
            return
        now = time.monotonic()
        with self._lock:
            dead = [bid for bid, b in self._batches.items()
                    if b.done and b.finished_at is not None
                    and now - b.finished_at > self.batch_ttl_s]
            for bid in dead:
                del self._batches[bid]
            self.n_evicted += len(dead)
        for bid in dead:
            log.info("evicted finished batch %s (ttl %.0fs)", bid,
                     self.batch_ttl_s)

    def batch(self, bid: str) -> Optional[_Batch]:
        self._evict()
        with self._lock:
            return self._batches.get(bid)

    def stats(self) -> Dict:
        self._evict()
        with self._lock:
            n_compiled, n_hits = self.n_compiled, self.n_cache_hits
            n_batches = len(self._batches)
            n_evicted = self.n_evicted
        with self._db_lock:
            cache_size = self.db.cache_size()
        return {"n_compiled": n_compiled, "n_cache_hits": n_hits,
                "n_batches": n_batches, "cache_size": cache_size,
                "n_evicted": n_evicted, "batch_ttl_s": self.batch_ttl_s,
                "workers": self.workers,
                "machine": ({"key": self.profile.key,
                             "pid": self.profile.pid,
                             "hbm_bw": self.profile.hbm_bw,
                             "peak_flops": dict(self.profile.peak_flops)}
                            if self.profile is not None else None)}

    # ------------------------------------------------------------------
    def _engine_for(self, init: Dict) -> Tuple[ProcessBackend,
                                               threading.Lock]:
        """One warm ProcessBackend per distinct (executor, arch, shape,
        cache-key) config, reused across batches — jax imports are paid
        once per worker, not once per client sweep."""
        from repro.configs.registry import arch_from_spec, shape_from_spec
        key = json.dumps(init, sort_keys=True)
        with self._lock:
            entry = self._engines.get(key)
            if entry is None:
                executor = executor_from_spec(init["executor"],
                                              allow_test=self.allow_test)
                engine = ProcessBackend(
                    executor, arch_from_spec(init["arch"]),
                    shape_from_spec(init["shape"]), workers=self.workers,
                    timeout_s=getattr(executor, "timeout_s", None),
                    db_path=self.db_path, shape_key=init.get("shape_key", ""),
                    mesh_key=init.get("mesh_key", ""))
                entry = (engine, threading.Lock())
                self._engines[key] = entry
            return entry

    def _run_batch(self, batch: _Batch):
        try:
            sk = batch.init.get("shape_key", "")
            mk = batch.init.get("mesh_key", "")
            pending: List[JobSpec] = []
            for jd in batch.jobs:
                spec = JobSpec.from_json(jd)
                hit = None
                if spec.signature:
                    with self._db_lock:
                        # mesh-axis jobs carry their own environment
                        # column; the init mesh_key covers the rest
                        hit = self.db.cache_get(spec.signature, sk,
                                                spec.mesh_key or mk,
                                                spec.eff_cid)
                if hit is not None and hit["status"] in (DONE, FAILED):
                    with self._lock:
                        self.n_cache_hits += 1
                    batch.push(JobOutcome(
                        spec.key, hit["status"], cost=hit["cost"],
                        error=hit["error"], cached=True).to_json())
                else:
                    pending.append(spec)
            if pending:
                engine, run_lock = self._engine_for(batch.init)
                by_key = {s.key: s for s in pending}
                puts: List[Dict] = []
                with run_lock:
                    for out in engine.run(pending):
                        spec = by_key.get(out.key)
                        if out.status == DONE and not out.cached:
                            with self._lock:
                                self.n_compiled += 1
                        # same policy as the Recorder: deterministic
                        # results enter the shared cache, transient ones
                        # (deadline double-loss, crash) never do
                        if (spec is not None and spec.signature
                                and not out.cached and not out.transient
                                and out.status in (DONE, FAILED)):
                            puts.append({
                                "signature": spec.signature, "shape": sk,
                                "mesh": spec.mesh_key or mk,
                                "cid": spec.eff_cid,
                                "status": out.status, "cost": out.cost,
                                "error": out.error})
                        batch.push(out.to_json())
                if puts:
                    with self._db_lock:
                        self.db.cache_put_many(puts)
            batch.finish()
        except Exception as e:
            # a server-side failure is an outage, not a verdict on the
            # jobs: finish with an error so clients fail their remaining
            # rows as *transient* (retryable, never cached)
            log.exception("batch %s failed server-side", batch.bid)
            batch.finish(error=f"{type(e).__name__}: {e}")


def _make_handler(app: SweepScoringServer):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):          # route to logging
            log.debug("%s - %s", self.address_string(), fmt % args)

        def _reply(self, code: int, obj: Dict):
            body = json.dumps(obj).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _authorized(self) -> bool:
            """Shared-secret check; replies 401 itself on failure.
            Constant-time compare — a scoring token is still a secret."""
            if app.token is None:
                return True
            got = self.headers.get("Authorization", "")
            ok = got.startswith("Bearer ") and hmac.compare_digest(
                got[len("Bearer "):], app.token)
            if not ok:
                self._reply(401, {"v": WIRE_VERSION,
                                  "error": "missing or bad bearer token"})
            return ok

        def do_POST(self):
            if not self._authorized():
                return
            if urlparse(self.path).path != "/v1/submit":
                return self._reply(404, {"error": f"no route {self.path}"})
            try:
                n = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(n))
                bid, resumed = app.submit(payload)
            except (WireVersionError, TypeError, ValueError, KeyError,
                    AttributeError) as e:
                return self._reply(400, {"v": WIRE_VERSION,
                                         "error": f"{type(e).__name__}: {e}"})
            self._reply(200, {"v": WIRE_VERSION, "batch": bid,
                              "resumed": resumed})

        def do_GET(self):
            if not self._authorized():
                return
            u = urlparse(self.path)
            q = parse_qs(u.query)
            if u.path == "/v1/health":
                return self._reply(200, {"v": WIRE_VERSION, "ok": True})
            if u.path == "/v1/stats":
                return self._reply(200, {"v": WIRE_VERSION, **app.stats()})
            if u.path == "/v1/outcomes":
                bid = (q.get("batch") or [""])[0]
                batch = app.batch(bid)
                if batch is None:
                    # an evicted/unknown batch is recoverable: the client
                    # resubmits its content-keyed payload
                    return self._reply(404, {"v": WIRE_VERSION,
                                             "error": f"unknown batch {bid}"})
                after = int((q.get("after") or ["0"])[0])
                wait = min(float((q.get("wait") or ["0"])[0]),
                           app.poll_cap_s)
                outs, done, error = batch.read(after, wait)
                return self._reply(200, {"v": WIRE_VERSION, "outcomes": outs,
                                         "done": done, "error": error})
            self._reply(404, {"error": f"no route {self.path}"})

    return Handler


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.backends.server",
        description="Sweep scoring server: fronts a warm process-worker "
                    "pool and a shared WAL score cache over HTTP "
                    "(see docs/sweep_engine.md, 'Remote scoring').")
    ap.add_argument("--db", required=True,
                    help="sqlite path of the shared score cache (WAL)")
    ap.add_argument("--workers", type=int, default=2,
                    help="process workers scoring unique programs")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8477)
    ap.add_argument("--allow-test-executors", action="store_true",
                    help="admit sleep/crash executor specs from clients "
                         "(fault-injection CI only — never in production)")
    ap.add_argument("--token", default=None,
                    help="shared-secret bearer token required on every "
                         "request (mandatory for non-loopback --host)")
    ap.add_argument("--batch-ttl-s", type=float, default=3600.0,
                    help="evict finished batches after this many seconds "
                         "(clients recover via resubmit-on-404)")
    ap.add_argument("--calibrate", action="store_true",
                    help="measure (or load) this host's MachineProfile "
                         "into the server DB's machine_cache at startup "
                         "and expose it in /v1/stats")
    args = ap.parse_args(argv)
    logging.basicConfig(
        level=logging.INFO,
        format="%(asctime)s %(name)s %(levelname)s %(message)s")
    srv = SweepScoringServer(args.db, workers=args.workers, host=args.host,
                             port=args.port,
                             allow_test=args.allow_test_executors,
                             token=args.token, batch_ttl_s=args.batch_ttl_s,
                             calibrate=args.calibrate)
    url = srv.start()
    print(f"sweep scoring server listening on {url} "
          f"(db={args.db}, workers={args.workers})", flush=True)
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()


if __name__ == "__main__":
    main()
