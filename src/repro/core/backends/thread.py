"""Thread backend: in-process scoring on a thread pool.

Preserves the PR-1 ``ParallelSweepRunner`` semantics exactly — shared
incumbents, exact pruning, soft (post-hoc, CPU-time) deadlines off the
main thread — by wrapping it.  ``workers=1`` degrades to a plain
in-thread loop, which is also the ``backend="sequential"`` mode.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Sequence

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.backends.base import JobOutcome, JobSpec, ScoringBackend


class ThreadBackend(ScoringBackend):
    name = "thread"

    def __init__(self, executor, cfg: ArchConfig, shape: ShapeConfig, *,
                 workers: int = 1, prune: bool = False,
                 prune_margin: float = 0.1):
        # imported here (not at module top) so monkeypatched
        # ParallelSweepRunner spies in tests keep observing construction
        from repro.core.executor import ParallelSweepRunner
        self.runner = ParallelSweepRunner(
            executor, cfg, shape, workers=workers,
            prune=prune, prune_margin=prune_margin)

    def run(self, jobs: Sequence[JobSpec],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobOutcome]:
        # JobSpec is field-compatible with SweepJob; the runner re-derives
        # bounds and ordering itself (idempotent with the Scheduler's)
        for res in self.runner.run(list(jobs), incumbents=incumbents):
            yield JobOutcome(
                key=res.job.key, status=res.status,
                cost=res.cost.as_dict() if res.cost is not None else None,
                error=res.error, transient=res.transient)
