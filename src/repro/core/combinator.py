"""Combinator: enumerate (provider x flag-subset x clause) combinations.

Mirrors ComPar's Combinator, which parses three JSON inputs (compilers+
flags, OpenMP directive clauses, RTL routines) and registers every
permutation in the DB.  The paper's combination-count formula

    sum_{i in C} (2^{n_i} - 1) * (2^{rtl + d} - 1)

is implemented verbatim (it is an upper bound: it counts clause *subsets*;
mutually exclusive clause values make the realizable set smaller — we also
report the exact enumerated count).
"""
from __future__ import annotations

import hashlib
import itertools
import json
import random
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.models.context import SegmentClause

#: default "directive clause" sweep space (the OpenMP schedule/chunk analogue)
DEFAULT_CLAUSE_SPACE: Dict[str, Tuple] = {
    "remat": ("none", "dots", "full"),
    "kernel": ("xla", "pallas"),
    "block_q": (256, 512),
    "block_k": (512, 1024),
    "scan_unroll": (1,),
    "mlstm_chunk": (256,),
    "moe_dispatch": ("sorted",),
    "cache_upcast": (True,),
    "decode_shardmap": (False,),
}

#: default "RTL routine" sweep space (global runtime knobs,
#: the omp_set_num_threads analogue)
DEFAULT_GLOBAL_SPACE: Dict[str, Tuple] = {
    "microbatches": (1, 2, 4),
    "donate": (True,),
    "opt_state_dtype": ("float32", "bfloat16"),
}


@dataclass(frozen=True)
class Combination:
    """One point of the per-segment sweep."""
    provider: str
    flags: FrozenSet[str]
    clause: SegmentClause

    @property
    def cid(self) -> str:
        blob = json.dumps(
            {"p": self.provider, "f": sorted(self.flags),
             "c": self.clause.key()}, sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]

    def label(self) -> str:
        fl = "+".join(sorted(self.flags)) or "-"
        return f"{self.provider}[{fl}]({self.clause.key()})"

    def to_json(self) -> Dict:
        return {"provider": self.provider, "flags": sorted(self.flags),
                "clause": vars(self.clause)}

    @classmethod
    def from_json(cls, d: Dict) -> "Combination":
        return cls(d["provider"], frozenset(d["flags"]),
                   SegmentClause(**d["clause"]))


def mapping_key(cfg, mesh, combo: "Combination", seg) -> str:
    """Physical content of (provider, flags) for one segment: the resolved
    logical->mesh mapping.  Two combinations whose providers resolve to the
    same mapping build the same program.  Without a mesh every mapping is a
    no-op (``Rules.constrain`` passes through, shardings are ``None``), so
    all providers collapse to one key.

    ``mesh`` may be a live ``jax.Mesh`` *or* a declarative
    :class:`~repro.core.meshspec.MeshSpec` — the mapping resolution only
    needs axis names and sizes, never device handles, so a swept mesh
    point is keyed without materializing anything.
    """
    from repro.core.meshspec import MeshSpec
    if isinstance(mesh, MeshSpec):
        if mesh.is_local:
            return "local"
        axis_sizes = mesh.axis_sizes()
    elif mesh is None:
        return "local"
    else:
        axis_sizes = dict(zip(mesh.axis_names,
                              (int(d) for d in mesh.devices.shape)))
    from repro.core.providers import get_provider
    m = get_provider(combo.provider).mapping(cfg, axis_sizes, combo.flags, seg)
    blob = json.dumps({"axes": axis_sizes,
                       "map": {k: m[k] for k in sorted(m)}},
                      sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


#: effective-cid format version.  v2 added the knob projection (the RTL
#: axis): bumping the version changes every hash, so score_cache rows
#: written by the pre-knob engine can never alias post-knob keys even
#: when the projected content is otherwise identical.
EFFECTIVE_CID_VERSION = 2


def effective_cid(combo: "Combination", relevant: FrozenSet[str],
                  map_key: str, knobs: "Optional[GlobalKnobs]" = None,
                  relevant_knobs: FrozenSet[str] = frozenset()) -> str:
    """The combination id *as seen by one segment's program*: only the
    clause fields that reach the segment, the resolved mapping, and the
    GlobalKnobs fields that reach the segment
    (``Segment.relevant_knob_fields``).  Combinations — and knob points —
    differing in irrelevant fields share one effective cid; that is what
    makes sweeping a non-reaching knob free: every knob point projects to
    the same cid, so the group compiles once.  This is the
    structural-score-cache key component next to the segment signature."""
    cl = {f: getattr(combo.clause, f) for f in sorted(relevant)}
    kn = {f: getattr(knobs, f) for f in sorted(relevant_knobs)} \
        if knobs is not None else {}
    blob = json.dumps({"v": EFFECTIVE_CID_VERSION, "map": map_key,
                       "clause": cl, "knobs": kn},
                      sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


@dataclass(frozen=True)
class GlobalKnobs:
    """Program-wide knobs (ComPar's RTL-routine analogue).

    Since the knob-axis refactor these are a *swept* dimension:
    ``ComParTuner.sweep(global_space=...)`` enumerates a grid of knob
    points and the fused plan's ``knobs`` are chosen by the joint
    argmin, not supplied by the caller.
    """
    microbatches: int = 1
    donate: bool = True
    opt_state_dtype: str = "float32"

    def key(self) -> str:
        return f"mb={self.microbatches},don={self.donate},osd={self.opt_state_dtype}"

    @property
    def kid(self) -> str:
        """Content id of this knob point (the knob analogue of
        ``Combination.cid``)."""
        blob = json.dumps(vars(self), sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:10]

    def to_json(self) -> Dict:
        return dict(vars(self))

    @classmethod
    def from_json(cls, d: Dict) -> "GlobalKnobs":
        return cls(**d)


def row_cid(combo: "Combination", knobs: Optional[GlobalKnobs] = None,
            mesh=None) -> str:
    """DB row id of one (combination, knob point, mesh point)
    registration.

    The default knob point keeps the bare combination cid, so projects
    registered by the pre-knob engine resume seamlessly; any other point
    qualifies the cid with the knob content id.  Content-determined: two
    sweeps registering the same (combo, knobs) share the row regardless
    of how the knob point was specified (fixed ``knobs=`` or a
    ``global_space`` grid).

    ``mesh`` is the *swept* mesh point (a
    :class:`~repro.core.meshspec.MeshSpec`) or ``None`` when the mesh is
    not swept — fixed-mesh and pre-mesh sweeps keep their unqualified
    ids and resume unchanged.  Every swept point qualifies the id with
    its content key, *including* the local point (``#local``): a swept
    row must never collide with (and silently resume as) a fixed-mesh
    row of the same project scored under a different topology."""
    rid = combo.cid if knobs is None or knobs == GlobalKnobs() \
        else f"{combo.cid}@{knobs.kid}"
    if mesh is not None:
        rid = f"{rid}#{mesh.mid}"
    return rid


def swept_knob_fields(space: Optional[Dict[str, Tuple]]) -> Tuple[str, ...]:
    """The knob fields a global space actually sweeps (>1 value) — the
    ``n_rtl`` the paper's combination-count formula should be charged
    for, as opposed to the field count of a fixed knobs instance."""
    if not space:
        return ()
    return tuple(sorted(k for k, v in space.items() if len(v) > 1))


def paper_combination_count(flags_per_provider: Sequence[int],
                            n_rtl: int, n_d: int) -> int:
    """The paper's formula: sum_i (2^{n_i}-1)(2^{rtl+d}-1)."""
    return sum((2 ** n - 1) * (2 ** (n_rtl + n_d) - 1)
               for n in flags_per_provider)


def flag_subsets(flags: Sequence[str], max_flags: Optional[int] = None):
    """All subsets of a provider's flags (including empty = bare provider)."""
    out = [frozenset()]
    upper = len(flags) if max_flags is None else min(max_flags, len(flags))
    for r in range(1, upper + 1):
        out.extend(frozenset(c) for c in itertools.combinations(flags, r))
    return out


def clause_grid(space: Dict[str, Tuple]) -> List[SegmentClause]:
    keys = sorted(space)
    out = []
    for combo in itertools.product(*(space[k] for k in keys)):
        out.append(SegmentClause(**dict(zip(keys, combo))))
    return out


def enumerate_combinations(
        providers: Sequence[str],
        clause_space: Optional[Dict[str, Tuple]] = None,
        *,
        max_flags: Optional[int] = None,
        budget: Optional[int] = None,
        seed: int = 0) -> List[Combination]:
    """Full cartesian enumeration, optionally budget-sampled.

    ``budget`` caps the number of combinations (uniform sample with a fixed
    seed — ComPar's recommendation to sweep a "sweet-spot" input applies to
    the sweep size too).
    """
    from repro.core.providers import get_provider
    space = clause_space or DEFAULT_CLAUSE_SPACE
    clauses = clause_grid(space)
    out: List[Combination] = []
    for pname in providers:
        p = get_provider(pname)
        for fl in flag_subsets(sorted(p.flags), max_flags):
            for cl in clauses:
                out.append(Combination(pname, fl, cl))
    if budget is not None and len(out) > budget:
        rng = random.Random(seed)
        out = rng.sample(out, budget)
    return out


def global_grid(space: Optional[Dict[str, Tuple]] = None) -> List[GlobalKnobs]:
    space = space or DEFAULT_GLOBAL_SPACE
    keys = sorted(space)
    return [GlobalKnobs(**dict(zip(keys, combo)))
            for combo in itertools.product(*(space[k] for k in keys))]


@dataclass(frozen=True)
class SweepSpec:
    """The typed sweep input: ComPar's three JSON files as one value.

    ``ComParTuner.sweep(spec=...)`` takes it directly; the fields mirror
    the JSON keys (:meth:`from_json` / :meth:`to_json` round-trip the
    wire form, :func:`load_sweep_json` reads a file into one):

    * ``providers`` — provider names to race (the "compilers")
    * ``clauses`` — the directive-clause grid (``clause_space``)
    * ``globals`` — the GlobalKnobs grid (``global_space``)
    * ``meshes`` — the topology axis (``mesh_space``); ``None`` = the
      mesh is not swept
    * ``kernel_space`` — the inner kernel-schedule grid (JSON key
      ``"kernels"``); ``None`` = no inner sweep

    :meth:`from_json` normalizes like the legacy loader did: unlisted
    clause/global fields are pinned to their default's first value, so a
    spec names ONLY the axes it sweeps.  A spec built by ``from_json``
    round-trips ``to_json`` exactly; a hand-built one may gain the
    pinned defaults on the way through.
    """

    providers: Tuple[str, ...] = ()
    clauses: Optional[Dict[str, Tuple]] = None
    globals: Optional[Dict[str, Tuple]] = None
    meshes: Optional[Tuple] = None          # tuple of MeshSpec
    kernel_space: Optional[Dict[str, Tuple]] = None

    @classmethod
    def from_json(cls, spec: Dict) -> "SweepSpec":
        from repro.core.meshspec import as_mesh_point
        providers = tuple(spec.get("providers", {}))
        clauses = {k: tuple(v) for k, v in spec.get("clauses", {}).items()}
        for k, v in DEFAULT_CLAUSE_SPACE.items():
            clauses.setdefault(k, (v[0],))
        gl = {k: tuple(v) for k, v in spec.get("globals", {}).items()}
        for k, v in DEFAULT_GLOBAL_SPACE.items():
            gl.setdefault(k, (v[0],))
        meshes = tuple(as_mesh_point(m) for m in spec["meshes"]) \
            if "meshes" in spec else None
        kernels = {k: tuple(v) for k, v in spec["kernels"].items()} \
            if "kernels" in spec else None
        return cls(providers, clauses, gl, meshes, kernels)

    def to_json(self) -> Dict:
        out: Dict = {"providers": {p: [] for p in self.providers}}
        if self.clauses is not None:
            out["clauses"] = {k: list(v) for k, v in self.clauses.items()}
        if self.globals is not None:
            out["globals"] = {k: list(v) for k, v in self.globals.items()}
        if self.meshes is not None:
            out["meshes"] = [m.to_json() for m in self.meshes]
        if self.kernel_space is not None:
            out["kernels"] = {k: list(v)
                              for k, v in self.kernel_space.items()}
        return out

    def __iter__(self):
        # the pre-SweepSpec loader returned a positional 4-tuple; keep
        # unpacking working for one release
        import warnings
        warnings.warn(
            "unpacking a SweepSpec as the legacy (providers, clause_space"
            ", global_space, mesh_space) 4-tuple is deprecated; use the "
            "named fields or ComParTuner.sweep(spec=...)",
            DeprecationWarning, stacklevel=2)
        yield list(self.providers)
        yield self.clauses
        yield self.globals
        yield list(self.meshes) if self.meshes is not None else None


def load_sweep_json(path: str) -> SweepSpec:
    """ComPar-style JSON sweep input.

    {
      "providers": {"tensor_par": ["shard_vocab"], "fsdp": []},
      "clauses":   {"remat": ["none","dots"], "kernel": ["xla"]},
      "globals":   {"microbatches": [1,2]},
      "meshes":    [null, {"data": 2, "model": 2}],
      "kernels":   {"kernel": ["xla","pallas"], "block_k": [512, 1024]}
    }

    ``meshes`` is the topology axis: a list of mesh points passed to
    ``sweep(mesh_space=...)``.  ``null`` is the local (meshless) point;
    an object is either the ``{"axis": size, ...}`` shorthand or the
    full MeshSpec wire form (``{"axes": [["data", 2]], "device_kind":
    "cpu"}``).  Absent = the mesh is not swept (``mesh_space=None``).
    ``kernels`` is the inner kernel-schedule grid
    (``sweep(kernel_space=...)``); absent = no inner sweep.

    Returns a :class:`SweepSpec` for ``sweep(spec=...)``.  Unpacking the
    result as the legacy ``(providers, clause_space, global_space,
    mesh_space)`` 4-tuple still works, with a DeprecationWarning.
    """
    with open(path) as f:
        return SweepSpec.from_json(json.load(f))
