"""Three-term roofline cost model (TPU v5e target).

    compute_s    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory_s     = HLO_bytes / (chips * HBM_bw)
    collective_s = collective_bytes_per_chip / link_bw

The Executor scores every ComParX combination with these terms; the
Optimal Plan Generator minimizes ``step_time = max(compute, memory,
collective)`` (the terms overlap on real hardware; max is the standard
roofline composition) plus fusion boundary costs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    link_bw: float = 50e9               # bytes/s per ICI link
    hbm_bytes: float = 16e9             # HBM capacity per chip
    dcn_bw: float = 25e9                # bytes/s per host, pod-to-pod


V5E = Hardware()


@dataclass
class CostTerms:
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    bytes_per_device: float = 0.0       # peak memory from memory_analysis
    detail: Dict[str, float] = field(default_factory=dict)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        out = {"compute_s": self.compute_s, "memory_s": self.memory_s,
               "collective_s": self.collective_s, "flops": self.flops,
               "bytes_accessed": self.bytes_accessed,
               "collective_bytes": self.collective_bytes,
               "bytes_per_device": self.bytes_per_device,
               "total_s": self.total_s}
        if self.detail:
            # keep the per-op detail on the wire: process workers ship
            # scores as dicts, and dropping detail there would make thread
            # and process sweeps record different rows
            out["detail"] = dict(self.detail)
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "CostTerms":
        return cls(compute_s=d.get("compute_s", 0.0),
                   memory_s=d.get("memory_s", 0.0),
                   collective_s=d.get("collective_s", 0.0),
                   flops=d.get("flops", 0.0),
                   bytes_accessed=d.get("bytes_accessed", 0.0),
                   collective_bytes=d.get("collective_bytes", 0.0),
                   bytes_per_device=d.get("bytes_per_device", 0.0),
                   detail=dict(d.get("detail") or {}))


def terms_from_analysis(flops: float, bytes_accessed: float,
                        coll_bytes_per_chip: float, n_chips: int,
                        hw: Hardware = V5E,
                        bytes_per_device: float = 0.0) -> CostTerms:
    """cost_analysis() totals are whole-program; divide by chip count."""
    return CostTerms(
        compute_s=flops / (n_chips * hw.peak_flops),
        memory_s=bytes_accessed / (n_chips * hw.hbm_bw),
        collective_s=coll_bytes_per_chip / hw.link_bw,
        flops=flops, bytes_accessed=bytes_accessed,
        collective_bytes=coll_bytes_per_chip,
        bytes_per_device=bytes_per_device)


# --- pruning lower bound -----------------------------------------------------
#
# ``combo_lower_bound`` is a *certified underestimate* of the score the
# Executor would produce for (segment, combination): it counts only matmul
# FLOPs and weight bytes that are guaranteed to appear as HLO ``dot`` ops
# (projection and dense-FFN matmuls; attention score matmuls and MoE
# expert matmuls are deliberately omitted — omission keeps the bound
# sound).  The sweep engine skips a combination whose bound already
# exceeds the segment's incumbent best: since bound <= true score, a
# pruned combination can never be the argmin, so pruning is exact.

#: minimum fwd+bwd dot-FLOP multiple of the forward pass, per remat mode
#: (bwd = dgrad + wgrad = 2x fwd dots; full remat re-runs the forward).
REMAT_FLOP_MULT = {"none": 3.0, "dots": 3.0, "full": 4.0}

#: guaranteed distinct-weight re-read count per training step, per remat
#: mode, for stack segments (fwd read + wgrad read; full remat streams
#: the weights a third time for the backward replay).
REMAT_WEIGHT_READS = {"none": 2.0, "dots": 2.0, "full": 3.0}

_DTYPE_ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4,
                   "float8_e4m3fn": 1, "float8_e5m2": 1}


def _itemsize(dtype: str) -> int:
    n = _DTYPE_ITEMSIZE.get(dtype)
    if n is None:
        import numpy as np
        n = int(np.dtype(dtype).itemsize)
    return n


def _block_proj_elems(cfg: ArchConfig, kind: str):
    """``(proj_elems, extra_fwd_flops_per_token)`` for one block.

    ``proj_elems`` counts weight elements of the dense ``x @ W``
    projections guaranteed to lower as HLO dots applied once per token:
    forward dot FLOPs are exactly ``2 * tokens * proj_elems`` and every
    element is streamed at least once per pass, so one number certifies
    both the FLOP and the weight-byte floor.  ``extra`` is additional
    guaranteed per-token forward dot FLOPs whose weights are re-read
    once per *scan step* rather than once per token (the sLSTM
    recurrent cell): they tighten the FLOP floor but MUST NOT enter the
    weight-byte floor — ``flops / 2`` would overestimate their unique
    weight traffic by the batch factor, breaking soundness.

    Dimension guards mirror the model's asserts (``mlstm_dims`` /
    ``slstm_dims``): a config those would reject returns zero floors
    instead of raising — the bound must never fail where scoring would
    merely record a failed combination.
    """
    d = cfg.d_model
    if kind.startswith("attn"):
        dh = cfg.head_dim_
        elems = (d * cfg.num_heads * dh            # wq
                 + 2.0 * d * cfg.num_kv_heads * dh  # wk + wv
                 + cfg.num_heads * dh * d)          # wo
        if kind == "attn" and cfg.d_ff:             # dense FFN (MoE: omitted)
            elems += (3 if cfg.glu else 2) * d * cfg.d_ff
        return elems, 0.0
    if kind == "rec":
        dr = int(cfg.expand_factor * d)
        # w_gate + w_x, w_a + w_i (full-sequence, outside the rglru scan),
        # w_out, then the block's dense FFN
        elems = 2.0 * d * dr + 2.0 * dr * dr + dr * d
        if cfg.d_ff:
            elems += (3 if cfg.glu else 2) * d * cfg.d_ff
        return elems, 0.0
    if kind == "mlstm":
        di = int(cfg.expand_factor * d)
        if di % cfg.num_heads:
            return 0.0, 0.0
        # w_up, wq/wk/wv ("bsi,ihd->bhsd", full-sequence), w_if, w_down
        elems = (d * 2.0 * di + 3.0 * di * di
                 + d * 2.0 * cfg.num_heads + di * d)
        return elems, 0.0
    if kind == "slstm":
        H = cfg.num_heads
        if d % H:
            return 0.0, 0.0
        dh = d // H
        ff = max(64, int(round(d * 4 / 3 / 64)) * 64)
        # zx gate projection ("bsd,dghe->bsghe" with 4*H*dh == 4d) + FFN
        elems = 4.0 * d * d + d * 2.0 * ff + ff * d
        # recurrent zr einsum ("bhe,hged->bghd") inside lax.scan: 2 FLOPs
        # per element of r=(H,4,dh,dh) per token, weights reused across
        # the batch each step
        extra = 8.0 * H * dh * dh
        return elems, extra
    return 0.0, 0.0


def _block_fwd_flops_per_token(cfg: ArchConfig, kind: str) -> float:
    """Guaranteed-present forward dot FLOPs per token for one block."""
    proj, extra = _block_proj_elems(cfg, kind)
    return 2.0 * proj + extra


def segment_forward_flops(cfg: ArchConfig, shape: ShapeConfig,
                          segment) -> float:
    """Lower bound on one forward pass's dot FLOPs through a segment."""
    tokens = shape.global_batch if shape.kind == "decode" \
        else shape.global_batch * shape.seq_len
    if segment.kind == "embed":
        return 0.0                               # a gather, not a dot
    if segment.kind == "head":
        return 2.0 * tokens * cfg.d_model * cfg.vocab_size
    per_super = sum(_block_fwd_flops_per_token(cfg, k)
                    for k in segment.pattern)
    return tokens * per_super * segment.repeats


def segment_weight_elems(cfg: ArchConfig, segment) -> float:
    """Certified count of distinct dot-operand weight elements in one
    segment.  Feeds the memory-traffic floor; float32 masters (rglru
    ``w_a``/``w_i``, sLSTM gates) are counted at ``cfg.dtype`` itemsize
    — underestimating traffic keeps the floor sound."""
    if segment.kind == "embed":
        return 0.0              # the table is gathered, not streamed as a dot
    if segment.kind == "head":
        return float(cfg.d_model) * cfg.vocab_size
    per_super = sum(_block_proj_elems(cfg, k)[0] for k in segment.pattern)
    return per_super * segment.repeats


def _batch_shard_degree(cfg: ArchConfig, shape: ShapeConfig, segment,
                        combo, mesh_axes) -> int:
    """How many ways this combination's provider shards the batch axis
    under ``mesh_axes`` (dict of mesh axis name -> size).

    Mirrors the timer's pspec resolution byte-for-byte: ``batch`` is
    the first logical axis every program resolves, against an empty
    used-set, through the provider mapping's candidate list with the
    divisibility fallback.  Anything unresolvable means "no certified
    batch sharding" and returns 1 (no collective floor) — sound.
    """
    try:
        from repro.core.providers import get_provider
        from repro.runtime.sharding import Rules
        mapping = get_provider(combo.provider).mapping(
            cfg, dict(mesh_axes), combo.flags, segment)
        rules = Rules(mapping, None)
        rules.axis_sizes = dict(mesh_axes)
        axes = rules._resolve_one("batch", shape.global_batch, set())
    except Exception:
        return 1
    g = 1
    for a in axes or ():
        g *= int(mesh_axes[a])
    return g


def combo_lower_bound(cfg: ArchConfig, shape: ShapeConfig, segment,
                      combo, n_chips: int = 1, hw: Hardware = V5E,
                      knobs=None, mesh_axes=None,
                      kernel_flops: float = 0.0) -> float:
    """Certified roofline lower bound (seconds) on scoring
    (segment, combination) under one GlobalKnobs point and one mesh.

    Three floors, composed with ``max`` exactly like
    :attr:`CostTerms.total_s`:

    * **compute**: guaranteed dot FLOPs for every block kind (attention
      projections/FFN, rglru full-sequence gates, mLSTM up/qkv/down,
      sLSTM gates + recurrent cell), times the remat fwd+bwd multiple,
      over aggregate peak FLOP/s.
    * **memory**: distinct dot-operand weight bytes times the
      guaranteed re-read count (fwd + wgrad; +1 for the full-remat
      replay; the grad-accumulation scan re-streams the weights every
      microbatch trip, so ``knobs.microbatches`` multiplies on train
      shapes), over aggregate HBM bandwidth.
    * **collective** (train, stack/head segments, ``mesh_axes`` given):
      if the provider shards the batch axis ``g`` ways, gradients must
      be combined across those ``g`` replicas — at least a ring pass
      of ``(g-1)/g * min(weight bytes, residual-activation bytes)``
      (XLA may all-gather activations instead of reducing grads; embed
      segments are excluded because their activation side is a tiny
      int32 token stream), spread over ``n_chips`` links.

    Certification under calibration: the bound divides by the *same*
    ``hw`` the executor's scorer divides by (``analyze_compiled`` uses
    ``executor.hw``), so a calibrated profile rescales bound and score
    together and ``bound <= score`` survives any profile.  ``knobs``
    terms only ever *add* guaranteed work (microbatching still
    processes every token once per pass; donation / ``opt_state_dtype``
    never remove dots), so the bound holds pointwise across the knob
    axis.  ``mesh_axes`` is the declarative axis->size dict of the
    point being scored (from ``MeshSpec.axis_sizes()`` or a live mesh);
    omitting it simply drops the collective floor.

    ``kernel_flops`` is the kernel autotuner's certified isolated flop
    count for the exact schedule this combination's clause selects
    (``repro.kernels.autotune`` — trip-count-exact HLO analysis of the
    same lowering the segment program embeds, so it is >= the minimum
    over measured variants and <= the program's own kernel flops).  It
    is disjoint from ``fwd`` by construction — the projection-dot floor
    deliberately omits attention-score/recurrence contractions — and is
    charged exactly once (the forward kernel runs at least once on every
    shape; the backward uses the reference vjp, and microbatching splits
    the same total), so adding it keeps ``bound <= score`` exact.
    ``0.0`` (unmeasured / no kernel axis) reproduces the old bound.
    """
    fwd = segment_forward_flops(cfg, shape, segment)
    if shape.kind != "train":
        mult = 1.0
    elif segment.kind == "stack":               # remat wraps stack blocks only
        mult = REMAT_FLOP_MULT.get(combo.clause.remat, 1.0)
    else:
        mult = 3.0                              # plain fwd + bwd
    compute_s = (fwd * mult + max(0.0, kernel_flops)) \
        / (n_chips * hw.peak_flops)

    itemsize = _itemsize(cfg.dtype)
    welems = segment_weight_elems(cfg, segment)
    memory_s = 0.0
    if welems:
        if shape.kind == "train":
            reads = REMAT_WEIGHT_READS.get(combo.clause.remat, 1.0) \
                if segment.kind == "stack" else 2.0
            mb = getattr(knobs, "microbatches", 1) if knobs is not None else 1
            reads *= max(1, int(mb))
        else:
            reads = 1.0
        memory_s = welems * itemsize * reads / (n_chips * hw.hbm_bw)

    collective_s = 0.0
    if (shape.kind == "train" and segment.kind in ("stack", "head")
            and mesh_axes and n_chips > 1 and welems):
        g = _batch_shard_degree(cfg, shape, segment, combo, mesh_axes)
        if g > 1:
            act_bytes = (shape.global_batch * shape.seq_len
                         * cfg.d_model * itemsize)
            w_bytes = welems * itemsize
            collective_s = ((g - 1) / g * min(w_bytes, act_bytes)
                            / (n_chips * hw.link_bw))

    return max(compute_s, memory_s, collective_s)


# --- analytic MODEL_FLOPS (the "useful compute" yardstick) -------------------

def active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top-k experts only)."""
    from repro.models.model import model_specs
    from repro.models.params import param_count
    total = param_count(model_specs(cfg))
    if not cfg.is_moe:
        return total
    # subtract the inactive expert fraction
    from repro.models.moe import moe_specs
    from repro.models.params import param_count as pc
    expert_leaf = moe_specs(cfg)
    per_layer_expert = sum(
        math.prod(s.shape) for k, s in expert_leaf.items()
        if k in ("wi", "wg", "wo"))
    n_moe_layers = cfg.num_layers - cfg.first_k_dense
    inactive_frac = 1.0 - cfg.experts_per_token / cfg.num_experts
    return int(total - n_moe_layers * per_layer_expert * inactive_frac)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D for training, 2*N*D for inference forward (N = active params).

    For decode shapes D = global_batch tokens (one step).
    """
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    tokens = shape.global_batch                     # decode: one token each
    flops = 2.0 * n * tokens
    # attention reads of the KV cache dominate decode compute for dense archs
    kinds = cfg.block_kinds()
    n_attn = sum(1 for k in kinds if k.startswith("attn"))
    ctx_len = min(shape.seq_len, cfg.window_size) if cfg.window_size \
        else shape.seq_len
    flops += 4.0 * tokens * n_attn * cfg.num_heads * cfg.head_dim_ * ctx_len
    return flops
