"""Three-term roofline cost model (TPU v5e target).

    compute_s    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory_s     = HLO_bytes / (chips * HBM_bw)
    collective_s = collective_bytes_per_chip / link_bw

The Executor scores every ComParX combination with these terms; the
Optimal Plan Generator minimizes ``step_time = max(compute, memory,
collective)`` (the terms overlap on real hardware; max is the standard
roofline composition) plus fusion boundary costs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    link_bw: float = 50e9               # bytes/s per ICI link
    hbm_bytes: float = 16e9             # HBM capacity per chip
    dcn_bw: float = 25e9                # bytes/s per host, pod-to-pod


V5E = Hardware()


@dataclass
class CostTerms:
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    bytes_per_device: float = 0.0       # peak memory from memory_analysis
    detail: Dict[str, float] = field(default_factory=dict)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        out = {"compute_s": self.compute_s, "memory_s": self.memory_s,
               "collective_s": self.collective_s, "flops": self.flops,
               "bytes_accessed": self.bytes_accessed,
               "collective_bytes": self.collective_bytes,
               "bytes_per_device": self.bytes_per_device,
               "total_s": self.total_s}
        if self.detail:
            # keep the per-op detail on the wire: process workers ship
            # scores as dicts, and dropping detail there would make thread
            # and process sweeps record different rows
            out["detail"] = dict(self.detail)
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "CostTerms":
        return cls(compute_s=d.get("compute_s", 0.0),
                   memory_s=d.get("memory_s", 0.0),
                   collective_s=d.get("collective_s", 0.0),
                   flops=d.get("flops", 0.0),
                   bytes_accessed=d.get("bytes_accessed", 0.0),
                   collective_bytes=d.get("collective_bytes", 0.0),
                   bytes_per_device=d.get("bytes_per_device", 0.0),
                   detail=dict(d.get("detail") or {}))


def terms_from_analysis(flops: float, bytes_accessed: float,
                        coll_bytes_per_chip: float, n_chips: int,
                        hw: Hardware = V5E,
                        bytes_per_device: float = 0.0) -> CostTerms:
    """cost_analysis() totals are whole-program; divide by chip count."""
    return CostTerms(
        compute_s=flops / (n_chips * hw.peak_flops),
        memory_s=bytes_accessed / (n_chips * hw.hbm_bw),
        collective_s=coll_bytes_per_chip / hw.link_bw,
        flops=flops, bytes_accessed=bytes_accessed,
        collective_bytes=coll_bytes_per_chip,
        bytes_per_device=bytes_per_device)


# --- pruning lower bound -----------------------------------------------------
#
# ``combo_lower_bound`` is a *certified underestimate* of the score the
# Executor would produce for (segment, combination): it counts only matmul
# FLOPs that are guaranteed to appear as HLO ``dot`` ops (projection and
# dense-FFN matmuls; attention score matmuls, MoE expert matmuls and
# recurrent cells are deliberately omitted — omission keeps the bound
# sound).  The sweep engine skips a combination whose bound already
# exceeds the segment's incumbent best: since bound <= true score, a
# pruned combination can never be the argmin, so pruning is exact.

#: minimum fwd+bwd dot-FLOP multiple of the forward pass, per remat mode
#: (bwd = dgrad + wgrad = 2x fwd dots; full remat re-runs the forward).
REMAT_FLOP_MULT = {"none": 3.0, "dots": 3.0, "full": 4.0}


def _block_fwd_flops_per_token(cfg: ArchConfig, kind: str) -> float:
    """Guaranteed-present forward dot FLOPs per token for one block."""
    if not kind.startswith("attn"):
        return 0.0          # recurrent/xLSTM cells: conservatively omitted
    d, dh = cfg.d_model, cfg.head_dim_
    qo = 2.0 * d * cfg.num_heads * dh * 2       # wq + wo
    kv = 2.0 * d * cfg.num_kv_heads * dh * 2    # wk + wv
    ffn = 0.0
    if kind == "attn" and cfg.d_ff:             # dense FFN (MoE: omitted)
        ffn = (3 if cfg.glu else 2) * 2.0 * d * cfg.d_ff
    return qo + kv + ffn


def segment_forward_flops(cfg: ArchConfig, shape: ShapeConfig,
                          segment) -> float:
    """Lower bound on one forward pass's dot FLOPs through a segment."""
    tokens = shape.global_batch if shape.kind == "decode" \
        else shape.global_batch * shape.seq_len
    if segment.kind == "embed":
        return 0.0                               # a gather, not a dot
    if segment.kind == "head":
        return 2.0 * tokens * cfg.d_model * cfg.vocab_size
    per_super = sum(_block_fwd_flops_per_token(cfg, k)
                    for k in segment.pattern)
    return tokens * per_super * segment.repeats


def combo_lower_bound(cfg: ArchConfig, shape: ShapeConfig, segment,
                      combo, n_chips: int = 1, hw: Hardware = V5E,
                      knobs=None) -> float:
    """Roofline lower bound (seconds) on scoring (segment, combination)
    under one GlobalKnobs point.

    Uses only the compute term: the memory-traffic estimator in
    ``runtime.hlo`` is not guaranteed to count parameter reads, so a
    byte-based term could overshoot the true score and break exactness.

    ``knobs`` keeps pruning exact across the swept knob axis.  The
    current terms are knob-invariant *by soundness*: microbatching
    still processes every token once per fwd/bwd pass (the accumulation
    adds and the 1/mb scale only add FLOPs), and donation /
    ``opt_state_dtype`` never remove dot ops — so the bound below holds
    for every knob point.  A future knob that legitimately lowers the
    floor (e.g. reduced-precision matmuls) must discount here.
    """
    fwd = segment_forward_flops(cfg, shape, segment)
    if shape.kind != "train":
        mult = 1.0
    elif segment.kind == "stack":               # remat wraps stack blocks only
        mult = REMAT_FLOP_MULT.get(combo.clause.remat, 1.0)
    else:
        mult = 3.0                              # plain fwd + bwd
    return fwd * mult / (n_chips * hw.peak_flops)


# --- analytic MODEL_FLOPS (the "useful compute" yardstick) -------------------

def active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top-k experts only)."""
    from repro.models.model import model_specs
    from repro.models.params import param_count
    total = param_count(model_specs(cfg))
    if not cfg.is_moe:
        return total
    # subtract the inactive expert fraction
    from repro.models.moe import moe_specs
    from repro.models.params import param_count as pc
    expert_leaf = moe_specs(cfg)
    per_layer_expert = sum(
        math.prod(s.shape) for k, s in expert_leaf.items()
        if k in ("wi", "wg", "wo"))
    n_moe_layers = cfg.num_layers - cfg.first_k_dense
    inactive_frac = 1.0 - cfg.experts_per_token / cfg.num_experts
    return int(total - n_moe_layers * per_layer_expert * inactive_frac)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D for training, 2*N*D for inference forward (N = active params).

    For decode shapes D = global_batch tokens (one step).
    """
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    tokens = shape.global_batch                     # decode: one token each
    flops = 2.0 * n * tokens
    # attention reads of the KV cache dominate decode compute for dense archs
    kinds = cfg.block_kinds()
    n_attn = sum(1 for k in kinds if k.startswith("attn"))
    ctx_len = min(shape.seq_len, cfg.window_size) if cfg.window_size \
        else shape.seq_len
    flops += 4.0 * tokens * n_attn * cfg.num_heads * cfg.head_dim_ * ctx_len
    return flops
