"""Sweep DB: sqlite-backed combination/result store with ComPar's three
operational modes — **New**, **Overwrite**, **Continue**.

Continue mode is the sweep's fault tolerance: a crashed or preempted sweep
resumes without re-running finished combinations (paper §4.2), and it is
also how more combinations are appended to an existing project.
"""
from __future__ import annotations

import json
import sqlite3
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.combinator import Combination

_SCHEMA = """
CREATE TABLE IF NOT EXISTS projects (
    name TEXT PRIMARY KEY,
    created REAL,
    config TEXT
);
CREATE TABLE IF NOT EXISTS combinations (
    project TEXT,
    segment TEXT,
    cid TEXT,
    spec TEXT,
    status TEXT DEFAULT 'pending',   -- pending | done | failed | invalid
    cost TEXT,
    error TEXT,
    updated REAL,
    PRIMARY KEY (project, segment, cid)
);
"""


class SweepDB:
    def __init__(self, path: str = ":memory:"):
        self.conn = sqlite3.connect(path)
        self.conn.executescript(_SCHEMA)
        self.conn.commit()

    # --- project modes -----------------------------------------------------
    def open_project(self, name: str, mode: str = "new",
                     config: Optional[Dict] = None) -> str:
        """Returns the (possibly suffixed) project name actually used."""
        cur = self.conn.execute(
            "SELECT name FROM projects WHERE name=?", (name,))
        exists = cur.fetchone() is not None
        if mode == "new":
            final = name
            i = 1
            while self._exists(final):
                final = f"{name}_{i}"       # append incremental index
                i += 1
        elif mode == "overwrite":
            final = name
            if exists:
                self.conn.execute(
                    "DELETE FROM combinations WHERE project=?", (name,))
                self.conn.execute(
                    "DELETE FROM projects WHERE name=?", (name,))
        elif mode == "continue":
            final = name
            if exists:
                self.conn.commit()
                return final
        else:
            raise ValueError(f"unknown mode {mode!r}")
        self.conn.execute(
            "INSERT INTO projects VALUES (?,?,?)",
            (final, time.time(), json.dumps(config or {})))
        self.conn.commit()
        return final

    def _exists(self, name: str) -> bool:
        cur = self.conn.execute(
            "SELECT 1 FROM projects WHERE name=?", (name,))
        return cur.fetchone() is not None

    # --- combinations ------------------------------------------------------
    def register(self, project: str, segment: str, combo: Combination):
        self.conn.execute(
            "INSERT OR IGNORE INTO combinations "
            "(project, segment, cid, spec, updated) VALUES (?,?,?,?,?)",
            (project, segment, combo.cid, json.dumps(combo.to_json()),
             time.time()))
        self.conn.commit()

    def status(self, project: str, segment: str, cid: str) -> Optional[str]:
        cur = self.conn.execute(
            "SELECT status FROM combinations WHERE project=? AND segment=? "
            "AND cid=?", (project, segment, cid))
        row = cur.fetchone()
        return row[0] if row else None

    def record(self, project: str, segment: str, cid: str, *,
               status: str, cost: Optional[Dict] = None,
               error: str = ""):
        self.conn.execute(
            "UPDATE combinations SET status=?, cost=?, error=?, updated=? "
            "WHERE project=? AND segment=? AND cid=?",
            (status, json.dumps(cost or {}), error, time.time(),
             project, segment, cid))
        self.conn.commit()

    def results(self, project: str,
                segment: Optional[str] = None) -> List[Dict]:
        q = ("SELECT segment, cid, spec, status, cost, error "
             "FROM combinations WHERE project=?")
        args: Tuple = (project,)
        if segment is not None:
            q += " AND segment=?"
            args = (project, segment)
        out = []
        for seg, cid, spec, status, cost, error in self.conn.execute(q, args):
            out.append({"segment": seg, "cid": cid,
                        "combo": Combination.from_json(json.loads(spec)),
                        "status": status,
                        "cost": json.loads(cost) if cost else None,
                        "error": error})
        return out

    def pending(self, project: str) -> List[Dict]:
        return [r for r in self.results(project) if r["status"] == "pending"]

    def done_count(self, project: str) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for st, n in self.conn.execute(
                "SELECT status, COUNT(*) FROM combinations WHERE project=? "
                "GROUP BY status", (project,)):
            out[st] = n
        return out
