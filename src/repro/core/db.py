"""Sweep DB: sqlite-backed combination/result store with ComPar's three
operational modes — **New**, **Overwrite**, **Continue**.

Continue mode is the sweep's fault tolerance: a crashed or preempted sweep
resumes without re-running finished combinations (paper §4.2), and it is
also how more combinations are appended to an existing project.
"""
from __future__ import annotations

import json
import sqlite3
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.combinator import Combination, GlobalKnobs, row_cid
from repro.core.meshspec import MeshSpec

_SCHEMA = """
CREATE TABLE IF NOT EXISTS projects (
    name TEXT PRIMARY KEY,
    created REAL,
    config TEXT
);
CREATE TABLE IF NOT EXISTS combinations (
    project TEXT,
    segment TEXT,
    cid TEXT,
    spec TEXT,
    status TEXT DEFAULT 'pending',   -- pending | done | failed | invalid
                                     --   | pruned | static
    cost TEXT,
    error TEXT,
    updated REAL,
    PRIMARY KEY (project, segment, cid)
);
CREATE TABLE IF NOT EXISTS score_cache (
    signature TEXT,                  -- Segment.signature(cfg, shape)
    shape TEXT,                      -- shape content key
    mesh TEXT,                       -- mesh content key ('local' = no mesh)
    cid TEXT,                        -- effective combination id
    status TEXT,                     -- done | failed
    cost TEXT,
    error TEXT,
    created REAL,
    total_s REAL,                    -- denormalized cost for keep-best upserts
    PRIMARY KEY (signature, shape, mesh, cid)
);
CREATE TABLE IF NOT EXISTS machine_cache (
    key TEXT PRIMARY KEY,            -- machine.profile_key(): versioned host id
    pid TEXT,                        -- profile content hash
    profile TEXT,                    -- MachineProfile JSON
    created REAL
);
CREATE TABLE IF NOT EXISTS kernel_cache (
    key TEXT,                        -- autotune.cache_key(): versioned
                                     --   kernel:v<N>:<tag>:<op>:<dims>
    variant TEXT,                    -- canonical schedule key (k=v join)
    status TEXT,                     -- done | failed
    time_s REAL,
    flops REAL,
    error TEXT,
    created REAL,
    PRIMARY KEY (key, variant)
);
CREATE TABLE IF NOT EXISTS plan_registry (
    arch TEXT,                       -- ArchConfig name
    shape TEXT,                      -- shape_key(): kind:seq_lenxbatch
    kind TEXT,                       -- shape kind (nearest-lookup filter)
    seq_len INTEGER,
    batch INTEGER,
    mesh TEXT,                       -- MeshSpec mid ('local' = no mesh)
    cache_tag TEXT,                  -- executor tag the plan was scored under
    plan TEXT,                       -- Plan.to_json blob
    total_s REAL,                    -- fused predicted total (argmin value)
    report TEXT,                     -- sweep report summary JSON
    created REAL,
    PRIMARY KEY (arch, shape, mesh, cache_tag)
);
"""


class SweepDB:
    def __init__(self, path: str = ":memory:"):
        # The sweep engine is the only writer; threads only read compiled
        # artifacts, so a single shared connection is safe.  ``path`` is
        # kept so the process backend can hand workers a read-only view
        # of the score cache (WAL allows concurrent readers).
        self.path = path
        self.conn = sqlite3.connect(path, check_same_thread=False)
        # WAL keeps readers off the writer's back on file-backed DBs and
        # makes batched commits cheap; both pragmas are no-ops on :memory:.
        self.conn.execute("PRAGMA journal_mode=WAL")
        self.conn.execute("PRAGMA synchronous=NORMAL")
        self.conn.executescript(_SCHEMA)
        if "total_s" not in {r[1] for r in self.conn.execute(
                "PRAGMA table_info(score_cache)")}:
            # pre-PR-4 DBs: the keep-best upsert compares costs in SQL,
            # so the total must live in its own column — backfill it from
            # the stored cost blobs (a NULL total would otherwise leave
            # legacy rows beatable only by status rank)
            try:
                self.conn.execute(
                    "ALTER TABLE score_cache ADD COLUMN total_s REAL")
            except sqlite3.OperationalError:
                # lost the migration race to another process opening the
                # same file; the column exists now — backfill is
                # idempotent, so run it regardless
                pass
            backfill = []
            for rowid, cost in self.conn.execute(
                    "SELECT rowid, cost FROM score_cache WHERE cost != ''"):
                try:
                    total = json.loads(cost).get("total_s")
                except (ValueError, AttributeError):
                    continue
                if total is not None:
                    backfill.append((total, rowid))
            self.conn.executemany(
                "UPDATE score_cache SET total_s=? WHERE rowid=?", backfill)
        self.conn.commit()

    # --- project modes -----------------------------------------------------
    def open_project(self, name: str, mode: str = "new",
                     config: Optional[Dict] = None) -> str:
        """Returns the (possibly suffixed) project name actually used."""
        cur = self.conn.execute(
            "SELECT name FROM projects WHERE name=?", (name,))
        exists = cur.fetchone() is not None
        if mode == "new":
            final = name
            i = 1
            while self._exists(final):
                final = f"{name}_{i}"       # append incremental index
                i += 1
        elif mode == "overwrite":
            final = name
            if exists:
                self.conn.execute(
                    "DELETE FROM combinations WHERE project=?", (name,))
                self.conn.execute(
                    "DELETE FROM projects WHERE name=?", (name,))
        elif mode == "continue":
            final = name
            if exists:
                self.conn.commit()
                return final
        else:
            raise ValueError(f"unknown mode {mode!r}")
        self.conn.execute(
            "INSERT INTO projects VALUES (?,?,?)",
            (final, time.time(), json.dumps(config or {})))
        self.conn.commit()
        return final

    def _exists(self, name: str) -> bool:
        cur = self.conn.execute(
            "SELECT 1 FROM projects WHERE name=?", (name,))
        return cur.fetchone() is not None

    # --- combinations ------------------------------------------------------
    def register(self, project: str, segment: str, combo: Combination):
        self.register_many(project, [(segment, combo)])

    def register_many(self, project: str, items: Iterable[Tuple]):
        """Register (segment, combination[, knobs[, mesh]]) rows in ONE
        transaction.

        Items are ``(segment, combo)`` 2-tuples, ``(segment, combo,
        knobs)`` 3-tuples — the knob axis — or ``(segment, combo, knobs,
        mesh)`` 4-tuples — the mesh/topology axis, where ``mesh`` is the
        swept :class:`~repro.core.meshspec.MeshSpec` point (``None`` =
        the mesh is not swept).  The row id is
        ``combinator.row_cid(combo, knobs, mesh)`` (the bare combination
        cid for the default/absent points, so pre-knob and pre-mesh
        projects resume unchanged) and the spec records the knob and
        mesh points for per-point fusion grouping.
        """
        now = time.time()
        rows = []
        for item in items:
            seg, c = item[0], item[1]
            kn = item[2] if len(item) > 2 else None
            mesh = item[3] if len(item) > 3 else None
            spec = c.to_json()
            if kn is not None:
                spec["knobs"] = kn.to_json()
            if mesh is not None:
                spec["mesh"] = mesh.to_json()
            rows.append((project, seg, row_cid(c, kn, mesh),
                         json.dumps(spec), now))
        self.conn.executemany(
            "INSERT OR IGNORE INTO combinations "
            "(project, segment, cid, spec, updated) VALUES (?,?,?,?,?)",
            rows)
        self.conn.commit()

    def status(self, project: str, segment: str, cid: str) -> Optional[str]:
        cur = self.conn.execute(
            "SELECT status FROM combinations WHERE project=? AND segment=? "
            "AND cid=?", (project, segment, cid))
        row = cur.fetchone()
        return row[0] if row else None

    def statuses(self, project: str) -> Dict[Tuple[str, str], str]:
        """All (segment, cid) -> status in one query (the resume check)."""
        return {(seg, cid): st for seg, cid, st in self.conn.execute(
            "SELECT segment, cid, status FROM combinations WHERE project=?",
            (project,))}

    def record(self, project: str, segment: str, cid: str, *,
               status: str, cost: Optional[Dict] = None,
               error: str = ""):
        """Record a result for a REGISTERED combination; raises KeyError on
        an unknown row instead of silently dropping the result (an UPDATE
        that matches nothing)."""
        self.record_many(project, [
            {"segment": segment, "cid": cid, "status": status,
             "cost": cost, "error": error}])

    def record_many(self, project: str, rows: Iterable[Dict]):
        """Record a batch of results in ONE transaction.

        Each row: {"segment", "cid", "status", "cost"?, "error"?}.
        Raises KeyError if any (segment, cid) was never registered.
        """
        rows = list(rows)
        if not rows:
            return
        now = time.time()
        cur = self.conn.executemany(
            "UPDATE combinations SET status=?, cost=?, error=?, updated=? "
            "WHERE project=? AND segment=? AND cid=?",
            [(r["status"], json.dumps(r.get("cost") or {}),
              r.get("error", ""), now, project, r["segment"], r["cid"])
             for r in rows])
        if cur.rowcount != len(rows):
            self.conn.rollback()
            known = self.statuses(project)
            missing = [(r["segment"], r["cid"]) for r in rows
                       if (r["segment"], r["cid"]) not in known]
            raise KeyError(
                f"record() for unregistered combination(s) in project "
                f"{project!r}: {missing or 'duplicate rows in batch'}")
        self.conn.commit()

    # --- cross-project structural score cache ------------------------------
    def cache_get(self, signature: str, shape: str, mesh: str,
                  cid: str) -> Optional[Dict]:
        cur = self.conn.execute(
            "SELECT status, cost, error FROM score_cache WHERE signature=? "
            "AND shape=? AND mesh=? AND cid=?", (signature, shape, mesh, cid))
        row = cur.fetchone()
        if row is None:
            return None
        return {"status": row[0],
                "cost": json.loads(row[1]) if row[1] else None,
                "error": row[2]}

    #: keep-best ranking of cache statuses (higher wins a conflict)
    _STATUS_RANK = "CASE %s WHEN 'done' THEN 2 WHEN 'failed' THEN 1 ELSE 0 END"

    def cache_put_many(self, entries: Iterable[Dict]):
        """entries: {"signature","shape","mesh","cid","status","cost"?,
        "error"?} — one transaction, insert-if-absent / keep-best.

        A conflicting row is replaced only when the incoming entry is
        strictly better: ``done`` beats ``failed``, and among two ``done``
        entries the lower ``total_s`` wins.  Ties keep the existing row
        (first-writer-wins), so a stale in-flight batch — another thread,
        another sweep process, or a remote scoring server's client — can
        never clobber a fresher equal-or-better score.  The comparison
        runs inside the upsert statement itself, so it is atomic even
        across processes sharing the DB file.
        """
        now = time.time()
        rows = []
        for e in entries:
            cost = e.get("cost") or {}
            rows.append((e["signature"], e["shape"], e["mesh"], e["cid"],
                         e["status"], json.dumps(cost), e.get("error", ""),
                         now, cost.get("total_s")))
        self.conn.executemany(
            "INSERT INTO score_cache "
            "(signature, shape, mesh, cid, status, cost, error, created, "
            " total_s) VALUES (?,?,?,?,?,?,?,?,?) "
            "ON CONFLICT(signature, shape, mesh, cid) DO UPDATE SET "
            "status=excluded.status, cost=excluded.cost, "
            "error=excluded.error, created=excluded.created, "
            "total_s=excluded.total_s "
            "WHERE (%s) < (%s) OR (score_cache.status='done' "
            "AND excluded.status='done' "
            # COALESCE: a legacy 'done' row whose backfill found no total
            # (cost blob without total_s) must stay beatable, not become
            # a NULL-compares-false fixed point
            "AND excluded.total_s < COALESCE(score_cache.total_s, 1e999))"
            % (self._STATUS_RANK % "score_cache.status",
               self._STATUS_RANK % "excluded.status"),
            rows)
        self.conn.commit()

    # --- calibrated machine profiles ----------------------------------------
    def machine_get(self, key: str) -> Optional[Dict]:
        cur = self.conn.execute(
            "SELECT profile FROM machine_cache WHERE key=?", (key,))
        row = cur.fetchone()
        if row is None:
            return None
        try:
            return json.loads(row[0])
        except ValueError:
            return None

    def machine_put(self, key: str, pid: str, profile: Dict):
        # recalibration replaces: newest measurement wins (the pid makes
        # the swap visible to anything that cached the old hash)
        self.conn.execute(
            "INSERT OR REPLACE INTO machine_cache VALUES (?,?,?,?)",
            (key, pid, json.dumps(profile), time.time()))
        self.conn.commit()

    # --- kernel-schedule microbenchmarks ------------------------------------
    def kernel_get(self, key: str) -> Dict[str, Dict]:
        """All measured variants under one (op, dims, tag) cache key:
        variant key -> {"status", "time_s", "flops", "error"}.  Version
        mismatches can't happen — the version lives in the key, so stale
        rows are simply never addressed (machine_cache policy)."""
        out: Dict[str, Dict] = {}
        for variant, status, time_s, flops, error in self.conn.execute(
                "SELECT variant, status, time_s, flops, error "
                "FROM kernel_cache WHERE key=?", (key,)):
            out[variant] = {"status": status,
                            "time_s": float(time_s or 0.0),
                            "flops": float(flops or 0.0),
                            "error": error or ""}
        return out

    def kernel_put_many(self, key: str, entries: Dict[str, Dict]):
        """Persist variant measurements; re-measurement replaces (the
        newest timing wins, like machine_put)."""
        now = time.time()
        self.conn.executemany(
            "INSERT OR REPLACE INTO kernel_cache VALUES (?,?,?,?,?,?,?)",
            [(key, variant, e["status"], float(e.get("time_s") or 0.0),
              float(e.get("flops") or 0.0), e.get("error", ""), now)
             for variant, e in entries.items()])
        self.conn.commit()

    # --- registered fused plans (the serving side's lookup table) -----------
    _PLAN_COLS = ("arch", "shape", "kind", "seq_len", "batch", "mesh",
                  "cache_tag", "plan", "total_s", "report", "created")

    def plan_put(self, row: Dict):
        """Register a fused plan under its deployment key ``(arch, shape,
        mesh, cache_tag)``.  INSERT OR REPLACE: a re-tuned plan for the
        same key supersedes the old one (newest-wins, like machine_put —
        the sweep that just ran has the freshest view of the hardware).
        """
        self.conn.execute(
            "INSERT OR REPLACE INTO plan_registry VALUES "
            "(?,?,?,?,?,?,?,?,?,?,?)",
            (row["arch"], row["shape"], row["kind"], int(row["seq_len"]),
             int(row["batch"]), row["mesh"], row.get("cache_tag", ""),
             row["plan"], row.get("total_s"), row.get("report", ""),
             time.time()))
        self.conn.commit()

    def plan_get(self, arch: str, shape: str, mesh: str,
                 cache_tag: str) -> Optional[Dict]:
        cur = self.conn.execute(
            "SELECT %s FROM plan_registry WHERE arch=? AND shape=? AND "
            "mesh=? AND cache_tag=?" % ", ".join(self._PLAN_COLS),
            (arch, shape, mesh, cache_tag))
        row = cur.fetchone()
        return dict(zip(self._PLAN_COLS, row)) if row else None

    def plan_query(self, arch: Optional[str] = None,
                   kind: Optional[str] = None, mesh: Optional[str] = None,
                   cache_tag: Optional[str] = None) -> List[Dict]:
        """Registered plans matching every given filter, in a
        deterministic order (the registry's nearest-shape fallback
        tie-breaks on it)."""
        clauses, args = [], []
        for col, val in (("arch", arch), ("kind", kind), ("mesh", mesh),
                         ("cache_tag", cache_tag)):
            if val is not None:
                clauses.append(f"{col}=?")
                args.append(val)
        q = "SELECT %s FROM plan_registry" % ", ".join(self._PLAN_COLS)
        if clauses:
            q += " WHERE " + " AND ".join(clauses)
        q += " ORDER BY arch, shape, mesh, cache_tag"
        return [dict(zip(self._PLAN_COLS, r))
                for r in self.conn.execute(q, args)]

    def cache_size(self) -> int:
        return self.conn.execute(
            "SELECT COUNT(*) FROM score_cache").fetchone()[0]

    def results(self, project: str,
                segment: Optional[str] = None) -> List[Dict]:
        # ORDER BY rowid: registration order, so argmin tie-breaks are
        # identical across sequential/parallel/cached sweeps.
        q = ("SELECT segment, cid, spec, status, cost, error "
             "FROM combinations WHERE project=?")
        args: Tuple = (project,)
        if segment is not None:
            q += " AND segment=?"
            args = (project, segment)
        q += " ORDER BY rowid"
        out = []
        for seg, cid, spec, status, cost, error in self.conn.execute(q, args):
            sd = json.loads(spec)
            out.append({"segment": seg, "cid": cid,
                        "combo": Combination.from_json(sd),
                        "knobs": GlobalKnobs.from_json(sd["knobs"])
                        if sd.get("knobs") else None,
                        "mesh": MeshSpec.from_json(sd["mesh"])
                        if sd.get("mesh") else None,
                        "status": status,
                        "cost": json.loads(cost) if cost else None,
                        "error": error})
        return out

    def pending(self, project: str) -> List[Dict]:
        return [r for r in self.results(project) if r["status"] == "pending"]

    def done_count(self, project: str) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for st, n in self.conn.execute(
                "SELECT status, COUNT(*) FROM combinations WHERE project=? "
                "GROUP BY status", (project,)):
            out[st] = n
        return out


class ScoreCacheReader:
    """Read-only ``score_cache`` access for out-of-process sweep workers.

    Opens its own connection in query-only mode: a worker can read cache
    entries the parent's Recorder flushed mid-run (WAL supports concurrent
    readers under one writer) but can never write or take the write lock.
    Every failure path degrades to a cache miss — a broken reader must
    never fail a job.
    """

    def __init__(self, path: str):
        self.conn = None
        if not path or path == ":memory:":
            return              # private in-memory DBs are not shareable
        try:
            conn = sqlite3.connect(path, check_same_thread=False, timeout=1.0)
            conn.execute("PRAGMA query_only=ON")
            self.conn = conn
        except sqlite3.Error:
            self.conn = None

    def get(self, signature: str, shape: str, mesh: str,
            cid: str) -> Optional[Dict]:
        if self.conn is None:
            return None
        try:
            cur = self.conn.execute(
                "SELECT status, cost, error FROM score_cache WHERE "
                "signature=? AND shape=? AND mesh=? AND cid=?",
                (signature, shape, mesh, cid))
            row = cur.fetchone()
        except sqlite3.Error:
            return None
        if row is None:
            return None
        return {"status": row[0],
                "cost": json.loads(row[1]) if row[1] else None,
                "error": row[2]}

    def close(self):
        if self.conn is not None:
            self.conn.close()
            self.conn = None
