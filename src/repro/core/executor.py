"""Executors: score one combination on one segment (or a whole program).

* :class:`DryRunExecutor` — the production path on this CPU container:
  ``jit(...).lower(...).compile()`` + roofline terms from the compiled
  artifact (cost_analysis + HLO collective parsing).  Per-combination
  deadlines make a straggling compile a recorded failure instead of a
  sweep-blocker (ComPar rejects failed combinations the same way).
* :class:`WallClockExecutor` — ComPar's literal empirical loop: run the
  program and take the median wall-clock.  Used on CPU for small configs
  (tests, examples, benchmark suites).
"""
from __future__ import annotations

import signal
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import Combination, GlobalKnobs
from repro.core.cost_model import CostTerms, Hardware, V5E, combo_lower_bound
from repro.core.segment import Segment
from repro.core.timer import segment_program
from repro.runtime.hlo import analyze_hlo


class CombinationFailed(Exception):
    """A combination could not be scored.

    ``transient`` distinguishes outcomes that depend on machine load or
    the time budget (deadline overruns, worker crashes) from deterministic
    failures (lowering / sharding errors).  Transient failures are
    retryable and must never enter the persistent score cache; the flag
    travels on the raising executor, so cacheability is decided where the
    failure happened instead of by substring-matching error text.
    """

    def __init__(self, msg: str = "", *, transient: bool = False):
        super().__init__(msg)
        self.transient = transient


@contextmanager
def deadline(seconds: Optional[int]):
    """Straggler guard.

    On the main thread: SIGALRM, which interrupts a hung compile.  Off the
    main thread (the worker-pool path) ``signal`` is unavailable
    (``ValueError: signal only works in main thread``), so we fall back to
    a soft deadline: the block runs to completion and is *then* failed if
    it overran — a straggler still becomes a recorded failure instead of a
    silent sweep-blocker.
    """
    if not seconds:
        yield
        return

    if threading.current_thread() is not threading.main_thread():
        # CPU time, not wall: with N workers sharing cores (and the GIL
        # during tracing), wall-clock would fail jobs at workers=N that
        # pass at workers=1.  Thread CPU time stays ~constant under
        # contention, keeping parallel and sequential sweeps in
        # agreement; it is lenient for XLA's internal threads, which is
        # the safe direction for a straggler guard.
        t0 = time.thread_time()
        yield
        if time.thread_time() - t0 > seconds:
            raise CombinationFailed(f"deadline {seconds}s exceeded (soft)",
                                    transient=True)
        return

    def handler(signum, frame):
        raise CombinationFailed(f"deadline {seconds}s exceeded",
                                transient=True)

    old = signal.signal(signal.SIGALRM, handler)
    signal.setitimer(signal.ITIMER_REAL, float(seconds))
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)


@contextmanager
def _mesh_scope(mesh):
    """jax.set_mesh when available (jax >= 0.6), else the Mesh context
    manager — same effect for lowering under a mesh."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield
    else:
        with mesh:
            yield


def lower_and_compile(fn, args, shardings, mesh, donate_argnums=()):
    kw = {}
    if mesh is not None and shardings is not None:
        kw["in_shardings"] = shardings
    if donate_argnums:
        kw["donate_argnums"] = tuple(donate_argnums)
    jitted = jax.jit(fn, **kw)
    if mesh is not None:
        with _mesh_scope(mesh):
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    else:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def analyze_compiled(lowered, compiled, n_chips: int,
                     hw: Hardware = V5E) -> CostTerms:
    """Roofline terms from the compiled (post-SPMD, per-device) module.

    XLA:CPU's cost_analysis counts while bodies once, so we use the
    call-graph HLO walk (``runtime.hlo.analyze_hlo``) — trip-count-exact
    flops, an HBM-traffic byte estimator, and ring-factor collective
    bytes.  All per-device.
    """
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    res = analyze_hlo(hlo)
    f_pd, b_pd, c_pd = res["flops"], res["bytes"], res["collective"]
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # jax < 0.5: one dict per device
        ca = ca[0] if ca else {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
               "output_bytes": getattr(ma, "output_size_in_bytes", 0),
               "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
               "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0)
                              + getattr(ma, "temp_size_in_bytes", 0))}
    except Exception:
        pass
    terms = CostTerms(
        compute_s=f_pd / hw.peak_flops,
        memory_s=b_pd / hw.hbm_bw,
        collective_s=c_pd / hw.link_bw,
        flops=f_pd * n_chips,
        bytes_accessed=b_pd * n_chips,
        collective_bytes=c_pd,
        bytes_per_device=mem.get("peak_bytes", 0))
    terms.detail.update({k: v for k, v in res.items()
                         if k.startswith("coll_")})
    terms.detail["xla_cost_analysis_flops"] = float(ca.get("flops", 0.0))
    terms.detail.update(mem)
    return terms


#: sentinel for "no per-job mesh override: use the executor's own mesh".
#: Distinct from ``None`` — a swept *local* point passes ``mesh=None``
#: explicitly to score meshless even on a fixed-mesh executor.
_OWN_MESH = object()


class DryRunExecutor:
    #: analytic scoring: concurrent workers don't perturb each other
    parallel_safe = True

    def __init__(self, mesh, hw: Hardware = V5E,
                 timeout_s: Optional[int] = 300):
        self.mesh = mesh
        self.hw = hw
        self.timeout_s = timeout_s
        self.n_chips = int(mesh.devices.size) if mesh is not None else 1

    @property
    def cache_tag(self) -> str:
        """Score-cache identity: scores from different executors (or
        hardware models) must never be served to each other."""
        return f"dryrun:{self.hw.name}"

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination,
                      knobs: Optional[GlobalKnobs] = None,
                      mesh=_OWN_MESH) -> CostTerms:
        # ``mesh`` is the swept topology point's materialized mesh (the
        # mesh axis: one executor scores every point of a mesh_space);
        # left unset, the executor's fixed mesh applies
        mesh = self.mesh if mesh is _OWN_MESH else mesh
        n_chips = int(mesh.devices.size) if mesh is not None else 1
        # donation is part of the lowered program (buffer aliasing), so a
        # swept `donate` knob genuinely changes what is scored; safe here
        # because the dry-run path never executes the compiled artifact
        donate = (0,) if (shape.kind == "train" and knobs is not None
                          and knobs.donate) else ()
        with deadline(self.timeout_s):
            try:
                fn, args, shardings = segment_program(
                    cfg, shape, seg, combo, mesh, knobs=knobs)
                lowered, compiled = lower_and_compile(
                    fn, args, shardings, mesh, donate_argnums=donate)
            except CombinationFailed:
                raise
            except Exception as e:  # sharding/lowering failure = invalid combo
                raise CombinationFailed(f"{type(e).__name__}: {e}") from e
        return analyze_compiled(lowered, compiled, n_chips, self.hw)


class WallClockExecutor:
    """Empirical timing on the local device(s) — ComPar's measurement loop."""

    #: concurrent timed runs contend on the device and corrupt medians
    parallel_safe = False

    def __init__(self, mesh=None, repeats: int = 5,
                 timeout_s: Optional[int] = 120):
        self.mesh = mesh
        self.repeats = repeats
        self.timeout_s = timeout_s
        self.n_chips = int(mesh.devices.size) if mesh is not None else 1

    @property
    def cache_tag(self) -> str:
        # empirical timings are hardware identity, so the tag embeds the
        # local platform: two hosts sharing a score DB must never serve
        # each other wall-clock medians measured on different silicon.
        # (The analytic DryRunExecutor embeds its hw MODEL name instead —
        # its scores are platform-independent by construction.)
        return f"wallclock:r{self.repeats}:{jax.devices()[0].platform}"

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination,
                      knobs: Optional[GlobalKnobs] = None,
                      mesh=_OWN_MESH) -> CostTerms:
        mesh = self.mesh if mesh is _OWN_MESH else mesh
        # NOTE: no buffer donation here — the timing loop re-calls the
        # compiled program with the same concrete buffers, and donated
        # arrays are deleted after the first call.  A swept `donate`
        # point therefore scores identically under wallclock (relevance
        # is over-inclusive, which costs a duplicate compile, never
        # correctness).
        with deadline(self.timeout_s):
            try:
                fn, args, shardings = segment_program(
                    cfg, shape, seg, combo, mesh, knobs=knobs)
                concrete = jax.tree.map(
                    lambda s: _materialize(s), args,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
                lowered, compiled = lower_and_compile(
                    fn, concrete, shardings, mesh)
                out = compiled(*concrete)
                jax.block_until_ready(out)
                times = []
                for _ in range(self.repeats):
                    t0 = time.perf_counter()
                    out = compiled(*concrete)
                    jax.block_until_ready(out)
                    times.append(time.perf_counter() - t0)
            except CombinationFailed:
                raise
            except Exception as e:
                raise CombinationFailed(f"{type(e).__name__}: {e}") from e
        wall = float(np.median(times))
        t = CostTerms(compute_s=wall)
        t.detail["wall_s"] = wall
        return t


class SleepExecutor:
    """Deterministic straggler: sleeps ``sleep_s`` per job *without* arming
    the deadline — the stand-in for a hung native compile that SIGALRM
    cannot interrupt.  Exists to exercise the process backend's hard
    (kill-based) timeout in tests and CI; never used in real sweeps."""

    parallel_safe = True

    def __init__(self, sleep_s: float = 3600.0,
                 timeout_s: Optional[float] = None):
        self.sleep_s = sleep_s
        self.timeout_s = timeout_s
        self.n_chips = 1

    @property
    def cache_tag(self) -> str:
        return f"sleep:{self.sleep_s}"

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination,
                      knobs: Optional[GlobalKnobs] = None,
                      mesh=None) -> CostTerms:
        time.sleep(self.sleep_s)
        return CostTerms(compute_s=self.sleep_s)


class CrashExecutor:
    """Kills its own process on every job — the stand-in for a segfaulting
    worker, used to exercise the process backend's crash detection and
    requeue-once-then-fail policy.  Never used in real sweeps."""

    parallel_safe = True

    def __init__(self, timeout_s: Optional[float] = None):
        self.timeout_s = timeout_s
        self.n_chips = 1

    @property
    def cache_tag(self) -> str:
        return "crash"

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination,
                      knobs: Optional[GlobalKnobs] = None,
                      mesh=None) -> CostTerms:
        import os
        os._exit(13)


# --- parallel, pruning sweep runner -----------------------------------------

# One *unique* program to score; ``segments`` lists every segment name
# whose (segment, combination) rows share it.  The canonical dataclass
# lives in backends.base (it is also the process/remote wire format) —
# one type, so Scheduler-built jobs and hand-built jobs can never drift.
from repro.core.backends.base import JobSpec as SweepJob  # noqa: E402


@dataclass
class JobResult:
    job: SweepJob
    status: str                       # done | failed | pruned
    cost: Optional[CostTerms] = None
    error: str = ""
    transient: bool = False           # deadline/crash — retryable, uncacheable


class ParallelSweepRunner:
    """Fan unique (segment, combination) programs across a thread pool.

    * ``workers=1`` degrades to a plain in-thread loop (no pool overhead).
    * With ``prune=True``, each job first compares its analytic roofline
      lower bound (:func:`~repro.core.cost_model.combo_lower_bound`)
      against the incumbent best score of every member segment; a job
      whose bound already exceeds all incumbents is skipped as
      ``pruned`` — exact, since bound <= true score (see cost_model).
      Jobs are dispatched cheapest-bound-first so incumbents tighten
      early.  ``prune_margin`` demands the bound exceed the incumbent by
      a relative margin before pruning (safety headroom).
    * Per-worker timeouts come from the wrapped executor's ``deadline``;
      off the main thread that is a soft deadline (see :func:`deadline`).
    """

    def __init__(self, executor, cfg: ArchConfig, shape: ShapeConfig, *,
                 workers: int = 1, prune: bool = False,
                 prune_margin: float = 0.1):
        # the exactness-critical prune predicate lives in ONE place
        # (backends.base.IncumbentTracker), shared with the process
        # backend so all backends prune — and therefore fuse — identically
        from repro.core.backends.base import IncumbentTracker
        self.executor = executor
        self.cfg = cfg
        self.shape = shape
        self.workers = max(1, int(workers))
        self.prune = prune
        self.prune_margin = prune_margin
        self.tracker = IncumbentTracker(prune, prune_margin)

    # ------------------------------------------------------------------
    def _pruned(self, job: SweepJob) -> bool:
        return self.tracker.pruned(job)

    def _observe(self, segments: Sequence[str], total_s: float):
        self.tracker.observe(segments, total_s)

    def _run_job(self, job: SweepJob) -> JobResult:
        if self._pruned(job):
            return JobResult(job, "pruned",
                             error=f"lower bound {job.bound_s:.3e}s > "
                                   f"incumbent best")
        kw = {}
        if job.mesh is not None:
            # a swept mesh point: materialize it (memoized per process —
            # many jobs share a point) and build under it instead of the
            # executor's own mesh.  Only passed when present, so
            # hand-built executors without the parameter stay usable.
            from repro.core.meshspec import MeshUnsatisfiable, cached_mesh
            try:
                kw["mesh"] = cached_mesh(job.mesh)
            except MeshUnsatisfiable as e:
                # environment-dependent, not a verdict on the combination:
                # another host (or a bigger device count) may satisfy it
                return JobResult(job, "failed", error=str(e), transient=True)
        try:
            cost = self.executor.score_segment(
                self.cfg, self.shape, job.seg, job.combo, knobs=job.knobs,
                **kw)
        except CombinationFailed as e:
            return JobResult(job, "failed", error=str(e),
                             transient=getattr(e, "transient", False))
        except Exception as e:
            # an analysis bug must fail the row, not abort the sweep (an
            # escaping exception would drop the tuner's buffered batches)
            return JobResult(job, "failed", error=f"{type(e).__name__}: {e}")
        self._observe(job.segments, cost.total_s)
        return JobResult(job, "done", cost=cost)

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[SweepJob],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobResult]:
        """Yield a :class:`JobResult` per job as each completes.

        ``incumbents``: segment name -> best known total_s, used to seed
        pruning (cache hits, Continue-mode rows)."""
        self.tracker.seed(incumbents)
        n_chips = getattr(self.executor, "n_chips", 1)
        hw = getattr(self.executor, "hw", V5E)
        mesh = getattr(self.executor, "mesh", None)
        fixed_axes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
            if mesh is not None else None
        for job in jobs:
            if job.bound_s <= 0.0:      # Scheduler-built jobs arrive bounded
                job.bound_s = combo_lower_bound(
                    self.cfg, self.shape, job.seg, job.combo,
                    job.mesh.n_devices if job.mesh is not None else n_chips,
                    hw, knobs=job.knobs,
                    mesh_axes=job.mesh.axis_sizes()
                    if job.mesh is not None else fixed_axes)
        ordered = sorted(jobs, key=lambda j: (j.bound_s, j.key))

        if self.workers == 1:
            for job in ordered:
                yield self._run_job(job)
            return

        with ThreadPoolExecutor(max_workers=self.workers) as pool:
            pending = {pool.submit(self._run_job, j) for j in ordered}
            while pending:
                finished, pending = wait(pending,
                                         return_when=FIRST_COMPLETED)
                for fut in finished:
                    yield fut.result()


def _materialize(sds: jax.ShapeDtypeStruct):
    if np.issubdtype(sds.dtype, np.integer):
        return jax.numpy.zeros(sds.shape, sds.dtype)
    key = jax.random.key(42)
    return (jax.random.normal(key, sds.shape, "float32") * 0.02
            ).astype(sds.dtype)
