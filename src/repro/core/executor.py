"""Executors: score one combination on one segment (or a whole program).

* :class:`DryRunExecutor` — the production path on this CPU container:
  ``jit(...).lower(...).compile()`` + roofline terms from the compiled
  artifact (cost_analysis + HLO collective parsing).  Per-combination
  deadlines make a straggling compile a recorded failure instead of a
  sweep-blocker (ComPar rejects failed combinations the same way).
* :class:`WallClockExecutor` — ComPar's literal empirical loop: run the
  program and take the median wall-clock.  Used on CPU for small configs
  (tests, examples, benchmark suites).
"""
from __future__ import annotations

import signal
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import Combination
from repro.core.cost_model import CostTerms, Hardware, V5E, combo_lower_bound
from repro.core.segment import Segment
from repro.core.timer import segment_program
from repro.runtime.hlo import analyze_hlo


class CombinationFailed(Exception):
    pass


@contextmanager
def deadline(seconds: Optional[int]):
    """Straggler guard.

    On the main thread: SIGALRM, which interrupts a hung compile.  Off the
    main thread (the worker-pool path) ``signal`` is unavailable
    (``ValueError: signal only works in main thread``), so we fall back to
    a soft deadline: the block runs to completion and is *then* failed if
    it overran — a straggler still becomes a recorded failure instead of a
    silent sweep-blocker.
    """
    if not seconds:
        yield
        return

    if threading.current_thread() is not threading.main_thread():
        # CPU time, not wall: with N workers sharing cores (and the GIL
        # during tracing), wall-clock would fail jobs at workers=N that
        # pass at workers=1.  Thread CPU time stays ~constant under
        # contention, keeping parallel and sequential sweeps in
        # agreement; it is lenient for XLA's internal threads, which is
        # the safe direction for a straggler guard.
        t0 = time.thread_time()
        yield
        if time.thread_time() - t0 > seconds:
            raise CombinationFailed(f"deadline {seconds}s exceeded (soft)")
        return

    def handler(signum, frame):
        raise CombinationFailed(f"deadline {seconds}s exceeded")

    old = signal.signal(signal.SIGALRM, handler)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@contextmanager
def _mesh_scope(mesh):
    """jax.set_mesh when available (jax >= 0.6), else the Mesh context
    manager — same effect for lowering under a mesh."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield
    else:
        with mesh:
            yield


def lower_and_compile(fn, args, shardings, mesh):
    kw = {}
    if mesh is not None and shardings is not None:
        kw["in_shardings"] = shardings
    jitted = jax.jit(fn, **kw)
    if mesh is not None:
        with _mesh_scope(mesh):
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    else:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def analyze_compiled(lowered, compiled, n_chips: int,
                     hw: Hardware = V5E) -> CostTerms:
    """Roofline terms from the compiled (post-SPMD, per-device) module.

    XLA:CPU's cost_analysis counts while bodies once, so we use the
    call-graph HLO walk (``runtime.hlo.analyze_hlo``) — trip-count-exact
    flops, an HBM-traffic byte estimator, and ring-factor collective
    bytes.  All per-device.
    """
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    res = analyze_hlo(hlo)
    f_pd, b_pd, c_pd = res["flops"], res["bytes"], res["collective"]
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # jax < 0.5: one dict per device
        ca = ca[0] if ca else {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
               "output_bytes": getattr(ma, "output_size_in_bytes", 0),
               "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
               "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0)
                              + getattr(ma, "temp_size_in_bytes", 0))}
    except Exception:
        pass
    terms = CostTerms(
        compute_s=f_pd / hw.peak_flops,
        memory_s=b_pd / hw.hbm_bw,
        collective_s=c_pd / hw.link_bw,
        flops=f_pd * n_chips,
        bytes_accessed=b_pd * n_chips,
        collective_bytes=c_pd,
        bytes_per_device=mem.get("peak_bytes", 0))
    terms.detail.update({k: v for k, v in res.items()
                         if k.startswith("coll_")})
    terms.detail["xla_cost_analysis_flops"] = float(ca.get("flops", 0.0))
    terms.detail.update(mem)
    return terms


class DryRunExecutor:
    #: analytic scoring: concurrent workers don't perturb each other
    parallel_safe = True

    def __init__(self, mesh, hw: Hardware = V5E,
                 timeout_s: Optional[int] = 300):
        self.mesh = mesh
        self.hw = hw
        self.timeout_s = timeout_s
        self.n_chips = int(mesh.devices.size) if mesh is not None else 1

    @property
    def cache_tag(self) -> str:
        """Score-cache identity: scores from different executors (or
        hardware models) must never be served to each other."""
        return f"dryrun:{self.hw.name}"

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination) -> CostTerms:
        with deadline(self.timeout_s):
            try:
                fn, args, shardings = segment_program(
                    cfg, shape, seg, combo, self.mesh)
                lowered, compiled = lower_and_compile(
                    fn, args, shardings, self.mesh)
            except CombinationFailed:
                raise
            except Exception as e:  # sharding/lowering failure = invalid combo
                raise CombinationFailed(f"{type(e).__name__}: {e}") from e
        return analyze_compiled(lowered, compiled, self.n_chips, self.hw)


class WallClockExecutor:
    """Empirical timing on the local device(s) — ComPar's measurement loop."""

    #: concurrent timed runs contend on the device and corrupt medians
    parallel_safe = False

    def __init__(self, mesh=None, repeats: int = 5,
                 timeout_s: Optional[int] = 120):
        self.mesh = mesh
        self.repeats = repeats
        self.timeout_s = timeout_s
        self.n_chips = int(mesh.devices.size) if mesh is not None else 1

    @property
    def cache_tag(self) -> str:
        return f"wallclock:r{self.repeats}"

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination) -> CostTerms:
        with deadline(self.timeout_s):
            try:
                fn, args, shardings = segment_program(
                    cfg, shape, seg, combo, self.mesh)
                concrete = jax.tree.map(
                    lambda s: _materialize(s), args,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
                lowered, compiled = lower_and_compile(
                    fn, concrete, shardings, self.mesh)
                out = compiled(*concrete)
                jax.block_until_ready(out)
                times = []
                for _ in range(self.repeats):
                    t0 = time.perf_counter()
                    out = compiled(*concrete)
                    jax.block_until_ready(out)
                    times.append(time.perf_counter() - t0)
            except CombinationFailed:
                raise
            except Exception as e:
                raise CombinationFailed(f"{type(e).__name__}: {e}") from e
        wall = float(np.median(times))
        t = CostTerms(compute_s=wall)
        t.detail["wall_s"] = wall
        return t


# --- parallel, pruning sweep runner -----------------------------------------

@dataclass
class SweepJob:
    """One *unique* program to score.  ``segments`` lists every segment
    name whose (segment, combination) rows share this program — the tuner
    fans the result back out to all of them."""
    key: str
    seg: Segment
    combo: Combination
    segments: Tuple[str, ...] = ()
    bound_s: float = 0.0


@dataclass
class JobResult:
    job: SweepJob
    status: str                       # done | failed | pruned
    cost: Optional[CostTerms] = None
    error: str = ""


class ParallelSweepRunner:
    """Fan unique (segment, combination) programs across a thread pool.

    * ``workers=1`` degrades to a plain in-thread loop (no pool overhead).
    * With ``prune=True``, each job first compares its analytic roofline
      lower bound (:func:`~repro.core.cost_model.combo_lower_bound`)
      against the incumbent best score of every member segment; a job
      whose bound already exceeds all incumbents is skipped as
      ``pruned`` — exact, since bound <= true score (see cost_model).
      Jobs are dispatched cheapest-bound-first so incumbents tighten
      early.  ``prune_margin`` demands the bound exceed the incumbent by
      a relative margin before pruning (safety headroom).
    * Per-worker timeouts come from the wrapped executor's ``deadline``;
      off the main thread that is a soft deadline (see :func:`deadline`).
    """

    def __init__(self, executor, cfg: ArchConfig, shape: ShapeConfig, *,
                 workers: int = 1, prune: bool = False,
                 prune_margin: float = 0.1):
        self.executor = executor
        self.cfg = cfg
        self.shape = shape
        self.workers = max(1, int(workers))
        self.prune = prune
        self.prune_margin = prune_margin
        self._lock = threading.Lock()
        self._incumbents: Dict[str, float] = {}

    # ------------------------------------------------------------------
    def _pruned(self, job: SweepJob) -> bool:
        if not self.prune or job.bound_s <= 0.0 or not job.segments:
            return False
        with self._lock:
            return all(
                s in self._incumbents and
                job.bound_s > self._incumbents[s] * (1.0 + self.prune_margin)
                for s in job.segments)

    def _observe(self, segments: Sequence[str], total_s: float):
        with self._lock:
            for s in segments:
                cur = self._incumbents.get(s)
                if cur is None or total_s < cur:
                    self._incumbents[s] = total_s

    def _run_job(self, job: SweepJob) -> JobResult:
        if self._pruned(job):
            return JobResult(job, "pruned",
                             error=f"lower bound {job.bound_s:.3e}s > "
                                   f"incumbent best")
        try:
            cost = self.executor.score_segment(
                self.cfg, self.shape, job.seg, job.combo)
        except CombinationFailed as e:
            return JobResult(job, "failed", error=str(e))
        except Exception as e:
            # an analysis bug must fail the row, not abort the sweep (an
            # escaping exception would drop the tuner's buffered batches)
            return JobResult(job, "failed", error=f"{type(e).__name__}: {e}")
        self._observe(job.segments, cost.total_s)
        return JobResult(job, "done", cost=cost)

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[SweepJob],
            incumbents: Optional[Dict[str, float]] = None
            ) -> Iterator[JobResult]:
        """Yield a :class:`JobResult` per job as each completes.

        ``incumbents``: segment name -> best known total_s, used to seed
        pruning (cache hits, Continue-mode rows)."""
        if incumbents:
            with self._lock:
                for s, v in incumbents.items():
                    cur = self._incumbents.get(s)
                    if cur is None or v < cur:
                        self._incumbents[s] = v
        n_chips = getattr(self.executor, "n_chips", 1)
        hw = getattr(self.executor, "hw", V5E)
        for job in jobs:
            job.bound_s = combo_lower_bound(
                self.cfg, self.shape, job.seg, job.combo, n_chips, hw)
        ordered = sorted(jobs, key=lambda j: (j.bound_s, j.key))

        if self.workers == 1:
            for job in ordered:
                yield self._run_job(job)
            return

        with ThreadPoolExecutor(max_workers=self.workers) as pool:
            pending = {pool.submit(self._run_job, j) for j in ordered}
            while pending:
                finished, pending = wait(pending,
                                         return_when=FIRST_COMPLETED)
                for fut in finished:
                    yield fut.result()


def _materialize(sds: jax.ShapeDtypeStruct):
    if np.issubdtype(sds.dtype, np.integer):
        return jax.numpy.zeros(sds.shape, sds.dtype)
    key = jax.random.key(42)
    return (jax.random.normal(key, sds.shape, "float32") * 0.02
            ).astype(sds.dtype)
