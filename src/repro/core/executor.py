"""Executors: score one combination on one segment (or a whole program).

* :class:`DryRunExecutor` — the production path on this CPU container:
  ``jit(...).lower(...).compile()`` + roofline terms from the compiled
  artifact (cost_analysis + HLO collective parsing).  Per-combination
  deadlines make a straggling compile a recorded failure instead of a
  sweep-blocker (ComPar rejects failed combinations the same way).
* :class:`WallClockExecutor` — ComPar's literal empirical loop: run the
  program and take the median wall-clock.  Used on CPU for small configs
  (tests, examples, benchmark suites).
"""
from __future__ import annotations

import signal
import time
from contextlib import contextmanager
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import Combination
from repro.core.cost_model import CostTerms, Hardware, V5E
from repro.core.segment import Segment
from repro.core.timer import segment_program
from repro.runtime.hlo import analyze_hlo


class CombinationFailed(Exception):
    pass


@contextmanager
def deadline(seconds: Optional[int]):
    """SIGALRM-based straggler guard (single-threaded compile path)."""
    if not seconds:
        yield
        return

    def handler(signum, frame):
        raise CombinationFailed(f"deadline {seconds}s exceeded")

    old = signal.signal(signal.SIGALRM, handler)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def lower_and_compile(fn, args, shardings, mesh):
    kw = {}
    if mesh is not None and shardings is not None:
        kw["in_shardings"] = shardings
    jitted = jax.jit(fn, **kw)
    if mesh is not None:
        with jax.set_mesh(mesh):
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    else:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def analyze_compiled(lowered, compiled, n_chips: int,
                     hw: Hardware = V5E) -> CostTerms:
    """Roofline terms from the compiled (post-SPMD, per-device) module.

    XLA:CPU's cost_analysis counts while bodies once, so we use the
    call-graph HLO walk (``runtime.hlo.analyze_hlo``) — trip-count-exact
    flops, an HBM-traffic byte estimator, and ring-factor collective
    bytes.  All per-device.
    """
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    res = analyze_hlo(hlo)
    f_pd, b_pd, c_pd = res["flops"], res["bytes"], res["collective"]
    ca = compiled.cost_analysis() or {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
               "output_bytes": getattr(ma, "output_size_in_bytes", 0),
               "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
               "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0)
                              + getattr(ma, "temp_size_in_bytes", 0))}
    except Exception:
        pass
    terms = CostTerms(
        compute_s=f_pd / hw.peak_flops,
        memory_s=b_pd / hw.hbm_bw,
        collective_s=c_pd / hw.link_bw,
        flops=f_pd * n_chips,
        bytes_accessed=b_pd * n_chips,
        collective_bytes=c_pd,
        bytes_per_device=mem.get("peak_bytes", 0))
    terms.detail.update({k: v for k, v in res.items()
                         if k.startswith("coll_")})
    terms.detail["xla_cost_analysis_flops"] = float(ca.get("flops", 0.0))
    terms.detail.update(mem)
    return terms


class DryRunExecutor:
    def __init__(self, mesh, hw: Hardware = V5E,
                 timeout_s: Optional[int] = 300):
        self.mesh = mesh
        self.hw = hw
        self.timeout_s = timeout_s
        self.n_chips = int(mesh.devices.size) if mesh is not None else 1

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination) -> CostTerms:
        with deadline(self.timeout_s):
            try:
                fn, args, shardings = segment_program(
                    cfg, shape, seg, combo, self.mesh)
                lowered, compiled = lower_and_compile(
                    fn, args, shardings, self.mesh)
            except CombinationFailed:
                raise
            except Exception as e:  # sharding/lowering failure = invalid combo
                raise CombinationFailed(f"{type(e).__name__}: {e}") from e
        return analyze_compiled(lowered, compiled, self.n_chips, self.hw)


class WallClockExecutor:
    """Empirical timing on the local device(s) — ComPar's measurement loop."""

    def __init__(self, mesh=None, repeats: int = 5,
                 timeout_s: Optional[int] = 120):
        self.mesh = mesh
        self.repeats = repeats
        self.timeout_s = timeout_s
        self.n_chips = int(mesh.devices.size) if mesh is not None else 1

    def score_segment(self, cfg: ArchConfig, shape: ShapeConfig,
                      seg: Segment, combo: Combination) -> CostTerms:
        with deadline(self.timeout_s):
            try:
                fn, args, shardings = segment_program(
                    cfg, shape, seg, combo, self.mesh)
                concrete = jax.tree.map(
                    lambda s: _materialize(s), args,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
                lowered, compiled = lower_and_compile(
                    fn, concrete, shardings, self.mesh)
                out = compiled(*concrete)
                jax.block_until_ready(out)
                times = []
                for _ in range(self.repeats):
                    t0 = time.perf_counter()
                    out = compiled(*concrete)
                    jax.block_until_ready(out)
                    times.append(time.perf_counter() - t0)
            except CombinationFailed:
                raise
            except Exception as e:
                raise CombinationFailed(f"{type(e).__name__}: {e}") from e
        wall = float(np.median(times))
        t = CostTerms(compute_s=wall)
        t.detail["wall_s"] = wall
        return t


def _materialize(sds: jax.ShapeDtypeStruct):
    if np.issubdtype(sds.dtype, np.integer):
        return jax.numpy.zeros(sds.shape, sds.dtype)
    key = jax.random.key(42)
    return (jax.random.normal(key, sds.shape, "float32") * 0.02
            ).astype(sds.dtype)
