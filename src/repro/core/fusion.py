"""Optimal Plan Generator: fuse per-segment winners into one plan.

Paper-faithful mode: independent per-segment argmin over all valid
combinations — ComPar's guarantee holds (the fused plan is never worse
than the best single-provider plan, in the scored metric).

Beyond-paper mode (``boundary_costs=True``): on a distributed mesh,
adjacent segments with different activation layouts pay a resharding
collective that ComPar's shared-memory setting never sees.  We charge
layout transitions and solve the resulting chain by Viterbi DP — still
exact, now layout-transition-aware.

Knob axis (``fuse_joint``): GlobalKnobs — the paper's RTL-routine
dimension — is swept as an outer axis.  Knobs are program-wide, so the
per-segment (or Viterbi) solves are independent *given* a knob point;
the joint ``(segment, combination, knobs)`` argmin therefore decomposes
exactly into one inner solve per knob point plus an outer argmin, and
the returned plan's ``knobs`` are chosen, not supplied.
"""
from __future__ import annotations

import logging
import math
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import Combination, GlobalKnobs
from repro.core.cost_model import CostTerms, Hardware, V5E
from repro.core.plan import Plan
from repro.core.providers import get_provider
from repro.core.segment import Segment, fragment
from repro.runtime.sharding import Rules

log = logging.getLogger("repro.fusion")


def _residual_pspec(cfg: ArchConfig, shape: ShapeConfig, mesh,
                    combo: Combination, seg: Segment):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if mesh is not None else {}
    mapping = get_provider(combo.provider).mapping(
        cfg, axis_sizes, combo.flags, seg)
    rules = Rules(mapping, mesh)
    if shape.kind == "decode":
        return rules.pspec(("batch", "embed"),
                           (shape.global_batch, cfg.d_model))
    return rules.pspec(("batch", "seq", "embed"),
                       (shape.global_batch, shape.seq_len, cfg.d_model))


def boundary_cost_s(cfg: ArchConfig, shape: ShapeConfig, mesh,
                    a: Combination, sa: Segment,
                    b: Combination, sb: Segment,
                    hw: Hardware = V5E) -> float:
    """Resharding cost of the residual stream between two segments."""
    if mesh is None:
        return 0.0
    pa = _residual_pspec(cfg, shape, mesh, a, sa)
    pb = _residual_pspec(cfg, shape, mesh, b, sb)
    if pa == pb:
        return 0.0
    if shape.kind == "decode":
        elems = shape.global_batch * cfg.d_model
    else:
        elems = shape.global_batch * shape.seq_len * cfg.d_model
    bytes_total = elems * np.dtype(cfg.dtype).itemsize
    chips = int(mesh.devices.size)
    return bytes_total / (chips * hw.link_bw)


def max_boundary_cost_s(cfg: ArchConfig, shape: ShapeConfig,
                        n_chips: int, hw: Hardware = V5E) -> float:
    """Upper bound on ONE boundary's resharding cost under any pair of
    combinations — the per-boundary unit of ``JobSpec.slack_s``.

    :func:`boundary_cost_s` is either 0 (same pspec, or no mesh) or the
    combination-independent constant ``residual_bytes / (chips *
    link_bw)``; this returns that constant (0 when meshless), so
    ``(n_segments - 1) * max_boundary_cost_s`` certifiably dominates the
    total transition cost of every possible chain.
    """
    if n_chips <= 1:
        return 0.0
    if shape.kind == "decode":
        elems = shape.global_batch * cfg.d_model
    else:
        elems = shape.global_batch * shape.seq_len * cfg.d_model
    return elems * np.dtype(cfg.dtype).itemsize / (n_chips * hw.link_bw)


def fuse(cfg: ArchConfig, shape: ShapeConfig, mesh,
         results: Dict[str, List[Tuple[Combination, CostTerms]]],
         knobs: GlobalKnobs = GlobalKnobs(), *,
         boundary_costs: bool = False, hw: Hardware = V5E) -> Plan:
    """results: segment name -> [(combination, cost)] (valid entries only).

    Returns the fused plan; per-segment predicted costs land in
    ``plan.meta``.
    """
    segs = fragment(cfg)
    for s in segs:
        if not results.get(s.name):
            raise ValueError(f"no valid combination for segment {s.name!r}")

    if not boundary_costs:
        chosen = {}
        meta_cost = {}
        for s in segs:
            combo, cost = min(results[s.name], key=lambda rc: rc[1].total_s)
            chosen[s.name] = combo
            meta_cost[s.name] = cost.total_s
        return Plan(chosen, knobs,
                    {"per_segment_s": meta_cost,
                     "predicted_total_s": sum(meta_cost.values()),
                     "fusion": "per-segment-argmin"})

    # --- Viterbi DP over the segment chain with transition costs ----------
    options = {s.name: results[s.name] for s in segs}
    back: List[Dict[int, Tuple[float, int]]] = []
    prev_costs = {i: rc[1].total_s
                  for i, rc in enumerate(options[segs[0].name])}
    for si in range(1, len(segs)):
        s_prev, s_cur = segs[si - 1], segs[si]
        cur: Dict[int, Tuple[float, int]] = {}
        for j, (cj, costj) in enumerate(options[s_cur.name]):
            best = (math.inf, -1)
            for i, (ci, _) in enumerate(options[s_prev.name]):
                t = boundary_cost_s(cfg, shape, mesh, ci, s_prev,
                                    cj, s_cur, hw)
                cand = prev_costs[i] + t
                if cand < best[0]:
                    best = (cand, i)
            cur[j] = (best[0] + costj.total_s, best[1])
        back.append(cur)
        prev_costs = {j: v[0] for j, v in cur.items()}
    # trace back
    j = min(prev_costs, key=prev_costs.get)
    total = prev_costs[j]
    chosen_idx = [0] * len(segs)
    chosen_idx[-1] = j
    for si in range(len(segs) - 1, 0, -1):
        j = back[si - 1][j][1]
        chosen_idx[si - 1] = j
    chosen = {s.name: options[s.name][chosen_idx[i]][0]
              for i, s in enumerate(segs)}
    meta_cost = {s.name: options[s.name][chosen_idx[i]][1].total_s
                 for i, s in enumerate(segs)}
    return Plan(chosen, knobs,
                {"per_segment_s": meta_cost, "predicted_total_s": total,
                 "fusion": "viterbi-boundary"})


def fuse_joint(cfg: ArchConfig, shape: ShapeConfig, mesh,
               per_knob: Dict[str, Dict[str, List[Tuple[Combination,
                                                        CostTerms]]]],
               knob_points: List[GlobalKnobs], *,
               boundary_costs: bool = False, hw: Hardware = V5E,
               mesh_points=None) -> Plan:
    """Joint argmin over ``(segment, combination, knobs[, mesh])``.

    ``per_knob``: knob kid -> (segment name -> valid [(combo, cost)]).
    Solves each knob point's chain with :func:`fuse` (per-segment argmin,
    or Viterbi when ``boundary_costs``), then takes the outer argmin of
    the predicted totals.  Ties break to the earliest point in
    ``knob_points`` order (strict ``<``), which is deterministic across
    backends.  A knob point missing a valid combination for some segment
    is skipped; if *every* point is unfusable the error lists each
    point's failure.

    With ``mesh_points`` (a list of
    :class:`~repro.core.meshspec.MeshSpec`) the mesh becomes the
    *outermost* axis: ``per_knob`` is then keyed ``mesh mid -> knob kid
    -> segment -> rows``, ``mesh`` (the fixed-mesh argument) is ignored,
    and each point's inner (knob x segment) solve runs under that
    point's own topology — materialized only when ``boundary_costs``
    needs a live mesh, since the boundary resharding penalty is exactly
    what makes plans *differ* across topologies.  The winning plan's
    ``plan.mesh`` is the CHOSEN point; ties break to the earliest point
    in ``mesh_points`` order.
    """
    if mesh_points is not None:
        best: Optional[Plan] = None
        mesh_totals: Dict[str, float] = {}
        failures = []
        for mp in mesh_points:
            table = per_knob.get(mp.mid) or {}
            try:
                # a live mesh is only needed to price boundary
                # reshardings; the per-segment argmin is mesh-blind
                live = mp.to_mesh() if boundary_costs else None
                plan = fuse_joint(cfg, shape, live, table, knob_points,
                                  boundary_costs=boundary_costs, hw=hw)
            except ValueError as e:
                failures.append(f"[{mp.key()}] {e}")
                continue
            plan.mesh = mp
            mesh_totals[mp.key()] = plan.meta["predicted_total_s"]
            if best is None or (plan.meta["predicted_total_s"]
                                < best.meta["predicted_total_s"]):
                best = plan
        if best is None:
            raise ValueError("no mesh point is fusable: "
                             + "; ".join(failures))
        if failures:
            # a dropped point shrinks the argmin silently otherwise —
            # e.g. boundary_costs needing a mesh THIS host can't build
            # even though a remote server scored it fine
            log.warning("mesh argmin skipped %d point(s): %s",
                        len(failures), "; ".join(failures))
            best.meta["mesh_failures"] = list(failures)
        if len(mesh_points) > 1:
            best.meta["fusion"] += "+mesh-argmin"
        best.meta["mesh_points"] = len(mesh_points)
        best.meta["per_mesh_total_s"] = mesh_totals
        return best

    best = None
    totals: Dict[str, float] = {}
    failures = []
    for kn in knob_points:
        table = per_knob.get(kn.kid) or {}
        try:
            plan = fuse(cfg, shape, mesh, table, kn,
                        boundary_costs=boundary_costs, hw=hw)
        except ValueError as e:
            failures.append(f"[{kn.key()}] {e}")
            continue
        totals[kn.key()] = plan.meta["predicted_total_s"]
        if best is None or (plan.meta["predicted_total_s"]
                            < best.meta["predicted_total_s"]):
            best = plan
    if best is None:
        raise ValueError("no knob point has a valid combination for every "
                         "segment: " + "; ".join(failures))
    if len(knob_points) > 1:
        best.meta["fusion"] += "+knob-argmin"
    best.meta["knob_points"] = len(knob_points)
    best.meta["per_knob_total_s"] = totals
    return best


def best_uniform(cfg: ArchConfig,
                 results: Dict[str, List[Tuple[Combination, CostTerms]]],
                 knobs: GlobalKnobs = GlobalKnobs()) -> Tuple[Plan, float]:
    """The best *single-provider-everywhere* plan (the paper's baseline).

    Only combinations valid on every segment qualify (a provider that
    fails on any segment cannot compile the whole program — exactly
    ComPar's 'compiler fails on benchmark' case)."""
    segs = fragment(cfg)
    by_cid: Dict[str, Dict[str, Tuple[Combination, CostTerms]]] = {}
    for s in segs:
        for combo, cost in results.get(s.name, []):
            by_cid.setdefault(combo.cid, {})[s.name] = (combo, cost)
    best: Optional[Tuple[Plan, float]] = None
    for cid, per_seg in by_cid.items():
        if len(per_seg) != len(segs):
            continue
        total = sum(c.total_s for _, c in per_seg.values())
        combo = next(iter(per_seg.values()))[0]
        if best is None or total < best[1]:
            plan = Plan({s.name: combo for s in segs}, knobs,
                        {"predicted_total_s": total, "fusion": "uniform"})
            best = (plan, total)
    if best is None:
        raise ValueError("no combination is valid on all segments")
    return best
