"""Calibrated per-device machine model (ROADMAP direction 4).

``cost_model.Hardware`` ships TPU-v5e *constants*; on any other silicon
those are guesses, so ``combo_lower_bound`` sits far below every real
score and prunes little.  This module measures what THIS host can
actually do — a matmul ladder per dtype (achievable peak FLOP/s), an
HBM/stream bandwidth probe, and collective latency/bandwidth points per
(mesh shape, collective kind) — and persists the result as a versioned
:class:`MachineProfile` in the ``machine_cache`` table beside
``score_cache``.

Resolution happens *at the scorer*, exactly like executor cache tags:
the process that scores a job (tuner parent, scoring server) calibrates
or loads its own host's profile and views it as a
:class:`~repro.core.cost_model.Hardware` via
:func:`hardware_from_profile`, with the built-in constants as the
fallback for anything unmeasured.  The view's ``name`` embeds the
profile content hash, so ``DryRunExecutor.cache_tag``
(``dryrun:<hw.name>``) automatically isolates calibrated scores from
constant-model scores — and two hosts with identical profiles share
cache rows.

Soundness contract: calibration can never break pruning exactness.  The
lower bound and the scorer divide by the *same* executor ``hw``
(``analyze_compiled`` uses ``executor.hw``), so rescaling the constants
rescales bound and score together and ``bound <= score`` is preserved
under any profile.  What calibration changes is *which term dominates*
— e.g. on CPU the measured FLOP/s is ~3 orders below v5e while
bandwidth is ~1.5 orders below, so the (tight) compute floor dominates
the score and the bound prunes far harder.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import logging
import time
from dataclasses import dataclass, field, replace
from typing import Dict, Optional

from repro.core.cost_model import Hardware, V5E

log = logging.getLogger("repro.machine")

#: bump on any change to what the microbenchmarks measure or how the
#: profile is keyed — old rows can then never alias new semantics.
PROFILE_VERSION = 1

#: matmul ladder sizes (square, per dtype); tiny = smoke/CI sizes.
_MATMUL_SIZES = (512, 1024, 2048)
_MATMUL_SIZES_TINY = (128, 256)
#: stream probe array bytes.
_STREAM_BYTES = 1 << 26          # 64 MiB
_STREAM_BYTES_TINY = 1 << 22     # 4 MiB
#: per-shard bytes for collective probes.
_COLL_BYTES = 1 << 22
_COLL_BYTES_TINY = 1 << 18
_DTYPES = ("bfloat16", "float32")


def profile_key(platform: str, device_kind: str, n_devices: int) -> str:
    """Versioned machine identity — the ``machine_cache`` primary key."""
    return f"machine:v{PROFILE_VERSION}:{platform}:{device_kind}:{n_devices}"


@dataclass(frozen=True)
class MachineProfile:
    """Measured capabilities of one host's devices.

    ``peak_flops`` maps dtype name -> achieved FLOP/s per device;
    ``hbm_bw`` is achieved stream bytes/s per device; ``collectives``
    maps ``"<kind>:<axis>=<size>:<shard_bytes>"`` -> {"s", "bytes",
    "bytes_s"} where ``bytes`` follows the analyzer's ring conventions
    (all-reduce = 2*r*(n-1)/n per device), so ``bytes_s`` is directly
    comparable to ``Hardware.link_bw``.
    """
    platform: str
    device_kind: str
    n_devices: int
    peak_flops: Dict[str, float] = field(default_factory=dict)
    hbm_bw: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    meta: Dict[str, object] = field(default_factory=dict)
    version: int = PROFILE_VERSION

    @property
    def key(self) -> str:
        return profile_key(self.platform, self.device_kind, self.n_devices)

    @property
    def pid(self) -> str:
        """Content hash: equal measurements -> equal id, on any host."""
        return hashlib.sha1(
            json.dumps(self.to_json(), sort_keys=True).encode()).hexdigest()

    def to_json(self) -> Dict:
        return {"platform": self.platform, "device_kind": self.device_kind,
                "n_devices": self.n_devices,
                "peak_flops": dict(self.peak_flops), "hbm_bw": self.hbm_bw,
                "collectives": {k: dict(v)
                                for k, v in self.collectives.items()},
                "meta": dict(self.meta), "version": self.version}

    @classmethod
    def from_json(cls, d: Dict) -> "MachineProfile":
        return cls(platform=d["platform"], device_kind=d["device_kind"],
                   n_devices=int(d["n_devices"]),
                   peak_flops={k: float(v)
                               for k, v in (d.get("peak_flops") or {}).items()},
                   hbm_bw=float(d.get("hbm_bw") or 0.0),
                   collectives={k: {kk: float(vv) for kk, vv in v.items()}
                                for k, v in (d.get("collectives") or {}).items()},
                   meta=dict(d.get("meta") or {}),
                   version=int(d.get("version", 0)))

    def best_link_bw(self) -> float:
        """Best measured collective bytes/s (0.0 when single-device)."""
        return max((v.get("bytes_s", 0.0)
                    for v in self.collectives.values()), default=0.0)


# --- microbenchmarks ---------------------------------------------------------

def _time_best(fn, *args, repeats: int = 3) -> float:
    """Best-of-N wall time of an already-jitted fn (first call warms)."""
    import jax
    jax.block_until_ready(fn(*args))            # compile + warm
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _matmul_peak(dtype: str, sizes, repeats: int) -> float:
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda a, b: a @ b)
    best = 0.0
    for n in sizes:
        try:
            x = jnp.ones((n, n), dtype=dtype)
            y = jnp.ones((n, n), dtype=dtype)
            t = _time_best(f, x, y, repeats=repeats)
        except Exception as e:           # dtype unsupported on this backend
            log.debug("matmul probe %s n=%d failed: %s", dtype, n, e)
            continue
        if t > 0:
            best = max(best, 2.0 * n ** 3 / t)
    return best


def _stream_bw(nbytes: int, repeats: int) -> float:
    import jax
    import jax.numpy as jnp
    n = max(1, nbytes // 4)
    x = jnp.ones((n,), dtype="float32")
    # scale+shift defeats copy-elision; traffic = read + write
    f = jax.jit(lambda a: a * 1.000001 + 0.5)
    t = _time_best(f, x, repeats=repeats)
    return 2.0 * x.nbytes / t if t > 0 else 0.0


def _collective_points(n_devices: int, shard_bytes: int,
                       repeats: int) -> Dict[str, Dict[str, float]]:
    """All-reduce / all-gather over a flat ring of all local devices.

    Bytes use the analyzer's ring conventions (``runtime.hlo``):
    all-reduce moves ``2*r*(n-1)/n`` per device, all-gather
    ``r*(n-1)/n`` — so the derived ``bytes_s`` lands in the same units
    as ``Hardware.link_bw`` and the scorer's ``collective_s``.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.meshspec import MeshSpec
    from repro.runtime.sharding import shard_map_compat

    out: Dict[str, Dict[str, float]] = {}
    if n_devices < 2:
        return out
    mesh = MeshSpec.of(data=n_devices).to_mesh()
    rows = max(1, shard_bytes // 4)
    x = jax.device_put(
        jnp.ones((rows * n_devices,), dtype="float32"),
        jax.sharding.NamedSharding(mesh, P("data")))
    r = rows * 4                                     # shard bytes per device
    probes = {
        "all_reduce": (lambda a: jax.lax.psum(a, "data"),
                       P("data"), P(), 2.0 * r * (n_devices - 1) / n_devices),
        "all_gather": (lambda a: jax.lax.all_gather(a, "data", tiled=True),
                       P("data"), P(), 1.0 * r * (n_devices - 1) / n_devices),
    }
    for kind, (body, in_spec, out_spec, conv_bytes) in probes.items():
        try:
            f = shard_map_compat(body, mesh=mesh, in_specs=in_spec,
                                 out_specs=out_spec)
            t = _time_best(jax.jit(f), x, repeats=repeats)
        except Exception as e:
            log.debug("collective probe %s failed: %s", kind, e)
            continue
        if t > 0:
            out[f"{kind}:data={n_devices}:{r}"] = {
                "s": t, "bytes": conv_bytes, "bytes_s": conv_bytes / t}
    return out


def calibrate(tiny: bool = False, repeats: int = 3) -> MachineProfile:
    """Run the microbenchmark suite on this host's default backend."""
    import jax
    devs = jax.devices()
    platform = jax.default_backend()
    device_kind = getattr(devs[0], "device_kind", "") or platform
    n = len(devs)
    t0 = time.perf_counter()
    sizes = _MATMUL_SIZES_TINY if tiny else _MATMUL_SIZES
    peaks = {dt: _matmul_peak(dt, sizes, repeats) for dt in _DTYPES}
    peaks = {k: v for k, v in peaks.items() if v > 0}
    bw = _stream_bw(_STREAM_BYTES_TINY if tiny else _STREAM_BYTES, repeats)
    coll = _collective_points(
        n, _COLL_BYTES_TINY if tiny else _COLL_BYTES, repeats)
    prof = MachineProfile(
        platform=platform, device_kind=device_kind, n_devices=n,
        peak_flops=peaks, hbm_bw=bw, collectives=coll,
        meta={"tiny": bool(tiny), "repeats": int(repeats),
              "calibrated_s": round(time.perf_counter() - t0, 3),
              "matmul_sizes": list(sizes)})
    log.info("calibrated %s: peak=%s hbm_bw=%.3g coll=%d pts (%.1fs)",
             prof.key, {k: f"{v:.3g}" for k, v in peaks.items()}, bw,
             len(coll), prof.meta["calibrated_s"])
    return prof


def load_or_calibrate(db, tiny: bool = False,
                      force: bool = False) -> MachineProfile:
    """Resolve this host's profile against ``db.machine_cache``.

    Version-mismatched or unreadable rows are recalibrated, never
    trusted — same policy as versioned executor cache tags.
    """
    import jax
    devs = jax.devices()
    key = profile_key(jax.default_backend(),
                      getattr(devs[0], "device_kind", "")
                      or jax.default_backend(), len(devs))
    if not force:
        row = db.machine_get(key)
        if row is not None:
            try:
                prof = MachineProfile.from_json(row)
                if prof.version == PROFILE_VERSION and prof.key == key:
                    return prof
            except (KeyError, TypeError, ValueError):
                pass
            log.warning("stale/corrupt machine profile %s: recalibrating", key)
    prof = calibrate(tiny=tiny)
    db.machine_put(prof.key, prof.pid, prof.to_json())
    return prof


def hardware_from_profile(profile: MachineProfile,
                          base: Hardware = V5E) -> Hardware:
    """View a profile as the scorer's ``Hardware``; unmeasured fields
    fall back to ``base``'s constants.

    ``peak_flops`` takes the best dtype on the ladder (achievable peak,
    matching the constant's bf16 meaning); ``link_bw`` takes the best
    measured collective point.  The name embeds the profile hash so
    ``DryRunExecutor.cache_tag`` keys calibrated scores separately per
    profile content.
    """
    peak = max(profile.peak_flops.values(), default=0.0)
    link = profile.best_link_bw()
    return replace(
        base,
        name=f"cal{PROFILE_VERSION}-{profile.platform}-{profile.pid[:8]}",
        peak_flops=peak or base.peak_flops,
        hbm_bw=profile.hbm_bw or base.hbm_bw,
        link_bw=link or base.link_bw)


def resolve_machine(machine, db) -> Optional[Hardware]:
    """Tuner/server-facing resolution of a ``machine=`` argument.

    ``None`` -> None (keep the constant model); ``"auto"`` ->
    load-or-calibrate against ``db`` (tiny ladder: the sweep should not
    stall minutes on first contact — run ``calibrate()`` offline for a
    full ladder); a :class:`MachineProfile` -> its Hardware view; a
    :class:`Hardware` -> itself.
    """
    if machine is None:
        return None
    if isinstance(machine, Hardware):
        return machine
    if isinstance(machine, MachineProfile):
        return hardware_from_profile(machine)
    if machine == "auto":
        return hardware_from_profile(load_or_calibrate(db, tiny=True))
    raise ValueError(f"machine must be None, 'auto', a MachineProfile or "
                     f"a Hardware; got {machine!r}")


def main(argv=None) -> int:
    """CLI: calibrate this host and persist/print the profile (CI smoke)."""
    ap = argparse.ArgumentParser(description="machine calibration")
    ap.add_argument("--db", default="", help="sweep DB path (persist here)")
    ap.add_argument("--tiny", action="store_true", help="smoke-size ladder")
    ap.add_argument("--force", action="store_true", help="recalibrate even "
                    "if a cached profile exists")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    if args.db:
        from repro.core.db import SweepDB
        db = SweepDB(args.db)
        prof = load_or_calibrate(db, tiny=args.tiny, force=args.force)
    else:
        prof = calibrate(tiny=args.tiny, repeats=args.repeats)
    print(json.dumps({"key": prof.key, "pid": prof.pid,
                      **prof.to_json()}, indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
