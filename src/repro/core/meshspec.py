"""MeshSpec: the declarative, wire-serializable mesh/topology point.

A ``jax.Mesh`` holds live device handles, so it can never cross a
process boundary — which is why meshed sweeps used to be locked out of
the process and remote scoring backends entirely.  :class:`MeshSpec` is
the content of a mesh *without* the devices: ordered ``(axis name,
size)`` pairs plus the device platform it must materialize on.  It is
pure JSON on the wire (``to_json``/``from_json``), and whichever process
ends up scoring a job calls :meth:`to_mesh` to rebuild the mesh against
*its own* local devices — a process worker, the HTTP scoring server, or
the parent all materialize the same spec independently and build
byte-identical programs.

MeshSpec is also the sweep's second outer axis
(``ComParTuner.sweep(mesh_space=[...])``): each spec is one swept
topology point, content-identified by :attr:`mid` — the versioned hash
that keys DB rows, incumbent scopes and the ``score_cache.mesh`` column,
so scores from different topologies can never alias.

The **local point** (no mesh at all) is ``MeshSpec(())`` — empty axes,
``to_mesh()`` returns ``None``, ``mid == "local"`` (matching the
historical cache key for meshless sweeps).  ``None`` entries in a
``mesh_space`` are coerced to it by :func:`as_mesh_point`.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

#: version of the mesh content key.  v1 was the pre-spec era: an
#: *unversioned* sha1 of a live mesh's axes/shape/platform blob.  v2 is
#: the MeshSpec content hash.  Bumping the version changes every hash,
#: so score_cache rows written under the old key format can never be
#: served to (or clobbered by) spec-keyed sweeps.
MESH_KEY_VERSION = 2


class MeshUnsatisfiable(ValueError):
    """This host cannot materialize the spec (not enough matching
    devices).  A *protocol* error on the scoring server — the client's
    request can never succeed here, so it must fail loudly (HTTP 400),
    not be retried as a transient outage."""


@dataclass(frozen=True)
class MeshSpec:
    """Axis names + sizes + device kind; ``()`` axes = the local point."""

    axes: Tuple[Tuple[str, int], ...] = ()
    device_kind: str = ""               # "" = any local platform

    def __post_init__(self):
        # tolerate list/dict inputs (JSON decoding, hand-written specs)
        axes = self.axes.items() if isinstance(self.axes, dict) else self.axes
        object.__setattr__(
            self, "axes", tuple((str(n), int(s)) for n, s in axes))
        for name, size in self.axes:
            if size < 1:
                raise ValueError(f"mesh axis {name!r} has size {size}")

    # --- convenience constructors -------------------------------------
    @classmethod
    def of(cls, device_kind: str = "", **axes: int) -> "MeshSpec":
        """``MeshSpec.of(data=2, model=2)`` (kwarg order = axis order)."""
        return cls(tuple(axes.items()), device_kind)

    @classmethod
    def from_mesh(cls, mesh) -> "MeshSpec":
        """Derive the spec of a live ``jax.Mesh``.

        ``device_kind`` is deliberately left unconstrained: it is an
        *explicit* materialization constraint (part of the content key
        when set), and baking the parent's platform in here would give a
        fixed live mesh and the equivalent hand-written spec different
        content keys — splitting the score cache for no reason.  (The
        meshless ``"local"`` key never carried a platform either; the
        executor ``cache_tag`` half of the environment column is what
        scopes scores to a scoring method.)
        """
        return cls(tuple(zip(mesh.axis_names,
                             (int(d) for d in mesh.devices.shape))))

    # --- content ------------------------------------------------------
    @property
    def is_local(self) -> bool:
        return not self.axes

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.axes)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(s for _, s in self.axes)

    @property
    def n_devices(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n

    def axis_sizes(self) -> Dict[str, int]:
        return dict(self.axes)

    def key(self) -> str:
        """Human-readable point label (the mesh analogue of
        ``GlobalKnobs.key``)."""
        if self.is_local:
            return "local"
        body = "x".join(f"{n}{s}" for n, s in self.axes)
        return f"{body}[{self.device_kind or 'any'}]"

    @property
    def mid(self) -> str:
        """Versioned content id: keys DB rows (``row_cid``), incumbent
        scopes and the ``score_cache.mesh`` column.  ``"local"`` for the
        local point — the historical meshless cache key."""
        if self.is_local:
            return "local"
        blob = json.dumps({"v": MESH_KEY_VERSION,
                           "axes": [list(a) for a in self.axes],
                           "kind": self.device_kind}, sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]

    # --- wire format --------------------------------------------------
    def to_json(self) -> Dict:
        return {"axes": [list(a) for a in self.axes],
                "device_kind": self.device_kind}

    @classmethod
    def from_json(cls, d: Dict) -> "MeshSpec":
        return cls(tuple((n, int(s)) for n, s in d.get("axes") or ()),
                   str(d.get("device_kind", "")))

    # --- materialization ----------------------------------------------
    def _local_devices(self):
        import jax
        return [d for d in jax.devices()
                if not self.device_kind
                or getattr(d, "platform", "") == self.device_kind]

    def check_local(self):
        """Raise :class:`MeshUnsatisfiable` unless this host can
        materialize the spec.  Cheap enough for submit-time validation
        (the scoring server rejects unsatisfiable specs with HTTP 400
        instead of burning workers on a request that can never score)."""
        if self.is_local:
            return
        have = len(self._local_devices())
        if have < self.n_devices:
            kind = self.device_kind or "any"
            raise MeshUnsatisfiable(
                f"mesh {self.key()} needs {self.n_devices} {kind!r} "
                f"device(s); this host has {have}")

    def to_mesh(self):
        """Materialize against *this process's* devices (``None`` for
        the local point).  Raises :class:`MeshUnsatisfiable` when the
        host can't satisfy the spec."""
        if self.is_local:
            return None
        import numpy as np
        from jax.sharding import Mesh
        self.check_local()
        devs = self._local_devices()[: self.n_devices]
        return Mesh(np.array(devs).reshape(self.shape), self.axis_names)


#: the local (meshless) sweep point
LOCAL = MeshSpec(())


def as_mesh_point(m) -> MeshSpec:
    """Coerce one ``mesh_space`` entry: ``None`` -> the local point,
    dicts -> spec (``{"data": 2}`` shorthand or the full
    ``{"axes": ..., "device_kind": ...}`` wire form), live meshes ->
    :meth:`MeshSpec.from_mesh`."""
    if m is None:
        return LOCAL
    if isinstance(m, MeshSpec):
        return m
    if isinstance(m, dict):
        if "axes" in m:
            return MeshSpec.from_json(m)
        d = dict(m)                      # {"data": 2, ...} shorthand;
        kind = d.pop("device_kind", "")  # "device_kind" is reserved
        return MeshSpec(tuple(d.items()), str(kind or ""))
    if hasattr(m, "axis_names") and hasattr(m, "devices"):
        return MeshSpec.from_mesh(m)
    raise TypeError(f"not a mesh point: {m!r}")


#: spec.mid -> materialized Mesh, per process.  A process's device set
#: is fixed for its lifetime, so materializing each spec once is safe —
#: and worth it: thread-backend jobs and warm process workers score many
#: jobs under the same point.
_MESH_CACHE: Dict[str, object] = {}


def cached_mesh(spec: Optional[MeshSpec]):
    """``spec.to_mesh()`` memoized per process (None passes through)."""
    if spec is None or spec.is_local:
        return None
    mesh = _MESH_CACHE.get(spec.mid)
    if mesh is None:
        mesh = spec.to_mesh()
        _MESH_CACHE[spec.mid] = mesh
    return mesh


def default_mesh_space(device_count: Optional[int] = None,
                       device_kind: str = "") -> List[MeshSpec]:
    """Topology presets derived from the detected devices: the local
    point, the flat data ring, and every 2-D ``data x model``
    factorization of ``device_count`` — the points
    ``sweep(mesh_space="auto")`` races.

    ``device_count=None`` detects via ``jax.device_count()`` (lazy: a
    module importing this one never pulls jax in).  Single-device hosts
    get just the local point.  Factor pairs are ordered data-major
    (``data >= model`` first), matching the usual batch-parallel bias;
    every spec is buildable on this host by construction.
    """
    if device_count is None:
        import jax
        device_count = jax.device_count()
    n = int(device_count)
    out = [LOCAL]
    if n <= 1:
        return out
    out.append(MeshSpec((("data", n),), device_kind))
    pairs = []
    for a in range(2, n + 1):
        if n % a == 0 and n // a >= 2:
            pairs.append((a, n // a))
    # data-major order: (4,2) before (2,4) on 8 devices
    for a, b in sorted(pairs, key=lambda p: (-p[0], p[1])):
        out.append(MeshSpec((("data", a), ("model", b)), device_kind))
    return out


def __getattr__(name: str):
    # PEP 562: DEFAULT_MESH_SPACE queries local devices, so it must not
    # run at import time (importing meshspec would initialize jax)
    if name == "DEFAULT_MESH_SPACE":
        return default_mesh_space()
    raise AttributeError(name)
