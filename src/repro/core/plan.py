"""Parallelization plans: per-segment Combination + global knobs.

A :class:`Plan` is ComParX's "output program": where ComPar emits a fused
C file, ComParX emits a serializable plan that the step builders apply to
the jitted program (sharding rules + remat + kernels + microbatching).
"""
from __future__ import annotations

import json
import logging
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ArchConfig
from repro.core.combinator import Combination, GlobalKnobs
from repro.core.meshspec import MeshSpec
from repro.core.providers import get_provider
from repro.core.segment import Segment, fragment
from repro.models.context import ModelContext, SegmentClause
from repro.runtime.sharding import Rules

log = logging.getLogger("repro.plan")


@dataclass
class Plan:
    segments: Dict[str, Combination]
    knobs: GlobalKnobs = field(default_factory=GlobalKnobs)
    meta: Dict[str, object] = field(default_factory=dict)
    #: the mesh/topology point the plan was fused for.  ``None`` =
    #: unswept (pre-mesh plans load unchanged); set by ``fuse_joint``
    #: when a ``mesh_space`` was swept — the CHOSEN topology, the mesh
    #: analogue of ``knobs``.
    mesh: Optional[MeshSpec] = None

    def to_json(self) -> Dict:
        return {"segments": {k: c.to_json() for k, c in self.segments.items()},
                "knobs": vars(self.knobs), "meta": self.meta,
                "mesh": self.mesh.to_json() if self.mesh is not None
                else None}

    @classmethod
    def from_json(cls, d: Dict) -> "Plan":
        return cls({k: Combination.from_json(v)
                    for k, v in d["segments"].items()},
                   GlobalKnobs(**d["knobs"]), d.get("meta", {}),
                   MeshSpec.from_json(d["mesh"]) if d.get("mesh") else None)

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "Plan":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def lint(self, cfg: ArchConfig, shape, *, trace: bool = True):
        """Certify this plan against ``(cfg, shape)`` without compiling.

        Thin wrapper over :func:`repro.analysis.analyze_plan` — returns
        the list of :class:`repro.analysis.Diagnostic`; empty means the
        plan passes every static rule."""
        from repro.analysis import analyze_plan
        return analyze_plan(cfg, shape, self, trace=trace)

    def describe(self) -> str:
        lines = [f"knobs: {self.knobs.key()}"]
        if self.mesh is not None:
            lines.insert(0, f"mesh: {self.mesh.key()}")
        for seg, c in sorted(self.segments.items()):
            lines.append(f"  {seg:8s} -> {c.label()}")
        return "\n".join(lines)


def uniform_plan(cfg: ArchConfig, provider: str,
                 flags=frozenset(), clause: Optional[SegmentClause] = None,
                 knobs: Optional[GlobalKnobs] = None) -> Plan:
    """Single-provider plan — the "one compiler for the whole program"
    baseline that ComPar's fusion is compared against."""
    clause = clause or SegmentClause()
    combo = Combination(provider, frozenset(flags), clause)
    return Plan({s.name: combo for s in fragment(cfg)},
                knobs or GlobalKnobs())


def dp_shards(mesh) -> int:
    """Number of data-parallel shards (pod x data axes)."""
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def build_contexts(cfg: ArchConfig, mesh, plan: Plan,
                   *, interpret: bool = True) -> Dict[str, ModelContext]:
    """Apply a plan: per-segment ModelContext with provider rules.

    A plan missing a segment (e.g. fused for a smaller config) gets that
    segment's context from the plan's first combination — loudly: the
    substitution is logged and recorded in ``plan.meta`` so partial plans
    stay visible instead of silently borrowing an arbitrary combination.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if mesh is not None else {}
    ctxs: Dict[str, ModelContext] = {}
    groups = dp_shards(mesh)
    substituted: Dict[str, Dict[str, str]] = {}
    for seg in fragment(cfg):
        combo = plan.segments.get(seg.name)
        if combo is None:
            donor, combo = next(iter(plan.segments.items()))
            log.warning(
                "plan has no combination for segment %r; substituting %s "
                "from segment %r", seg.name, combo.label(), donor)
            substituted[seg.name] = {"from": donor, "combo": combo.label()}
        provider = get_provider(combo.provider)
        mapping = provider.mapping(cfg, axis_sizes, combo.flags, seg)
        ctxs[seg.name] = ModelContext(
            rules=Rules(mapping, mesh), clause=combo.clause,
            moe_groups=groups, interpret=interpret)
    if substituted:
        plan.meta.setdefault("substituted_segments", {}).update(substituted)
    return ctxs


def segment_rules(cfg: ArchConfig, mesh, plan: Plan) -> Dict[str, Rules]:
    return {k: c.rules for k, c in
            build_contexts(cfg, mesh, plan).items()}
