from repro.core.providers.base import (  # noqa: F401
    Provider, all_providers, get_provider, register,
)
import repro.core.providers.tensor_par  # noqa: F401
import repro.core.providers.fsdp        # noqa: F401
import repro.core.providers.hybrid2d    # noqa: F401
import repro.core.providers.expert_par  # noqa: F401
