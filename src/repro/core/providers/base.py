"""Strategy-provider base class — ComParX's analogue of an S2S compiler.

Each provider turns (arch config, mesh, flag subset, segment) into a
logical->physical sharding mapping (a ``Rules`` dict).  Like ComPar's
Cetus/AutoPar/Par4All, providers differ in philosophy, succeed on
different segments, and expose their own flags; the Combinator sweeps
(provider x flag-subset x clause) per segment and the Optimal Plan
Generator fuses the winners.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Mapping

from repro.configs.base import ArchConfig
from repro.core.segment import Segment

# logical axes that are never sharded, shared by every provider
_COMMON = {"layers": None, "head_dim": None, "conv": None}


class Provider:
    name: str = "base"
    #: flag name -> description (the "compiler flags" of this provider)
    flags: Dict[str, str] = {}

    def applicable(self, cfg: ArchConfig, segment: Segment) -> bool:
        return True

    def mapping(self, cfg: ArchConfig, mesh_axes: Mapping[str, int],
                flags: FrozenSet[str], segment: Segment) -> Dict[str, object]:
        raise NotImplementedError

    # ------------------------------------------------------------------
    def _common(self) -> Dict[str, object]:
        return dict(_COMMON)

    @staticmethod
    def _kv_strategy(cfg: ArchConfig, mesh_axes: Mapping[str, int]):
        """Shard kv heads on the model axis when divisible, else shard the
        KV-cache sequence dim (flash-decode + LSE-combine territory)."""
        tp = mesh_axes.get("model", 1)
        if cfg.num_kv_heads % tp == 0:
            return {"kv_heads": "model", "kv_seq": None}
        return {"kv_heads": None, "kv_seq": "model"}

    def describe(self) -> str:
        return f"{self.name}: flags={sorted(self.flags)}"


_REGISTRY: Dict[str, Provider] = {}


def register(p: Provider) -> Provider:
    _REGISTRY[p.name] = p
    return p


def get_provider(name: str) -> Provider:
    return _REGISTRY[name]


def all_providers():
    return dict(_REGISTRY)
