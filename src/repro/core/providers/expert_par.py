"""ExpertPar: expert parallelism for MoE segments — experts over the
``model`` axis, all-to-all style dispatch, optional TP attention."""
from __future__ import annotations

from repro.core.providers.base import Provider, register


class ExpertPar(Provider):
    name = "expert_par"
    flags = {
        "tp_attention": "also tensor-shard attention heads over model",
        "fsdp_dense": "FSDP the non-expert params over the data axis",
        "2d_experts": "shard expert ffn dim over data (experts x data 2D)",
    }

    def applicable(self, cfg, segment):
        return segment.kind != "stack" or segment.has_moe

    def mapping(self, cfg, mesh_axes, flags, segment):
        dense_axis = ["data", None] if "fsdp_dense" in flags else None
        m = self._common()
        m.update({
            "experts": ["model", None],
            "expert_ffn": (["data", None] if "2d_experts" in flags
                           else None),
            "embed": dense_axis,
            "vocab": dense_axis,
            "ffn": dense_axis,
            "rnn": dense_axis,
            "heads": ["model", None] if "tp_attention" in flags else None,
            "batch": [("pod", "data"), None],
            "seq": None,
        })
        if "tp_attention" in flags:
            m.update(self._kv_strategy(cfg, mesh_axes))
        else:
            m.update({"kv_heads": None, "kv_seq": None})
        return m


register(ExpertPar())
