"""FullyShardedDP: ZeRO-3 — params sharded, gathered per use;
gradients reduce-scattered; pure data-parallel activations."""
from __future__ import annotations

from repro.core.providers.base import Provider, register


class FullyShardedDP(Provider):
    name = "fsdp"
    flags = {
        "shard_both_axes": "shard params over (data, model), not just data",
        "dp_over_model": "also use the model axis for batch data-parallelism",
    }

    def mapping(self, cfg, mesh_axes, flags, segment):
        fs = ("data", "model") if "shard_both_axes" in flags else ("data",)
        m = self._common()
        m.update({
            # used-axis tracking shards exactly one (leading) dim per param
            "embed": [fs, None],
            "vocab": [fs, None],
            "ffn": [fs, None],
            "expert_ffn": [fs, None],
            "experts": [fs, None],
            "rnn": [fs, None],
            "heads": [fs, None],
            "kv_heads": None,
            "kv_seq": None,
            "seq": None,
            "batch": ([("pod", "data", "model"), ("pod", "data"), None]
                      if "dp_over_model" in flags
                      else [("pod", "data"), None]),
        })
        return m


register(FullyShardedDP())
