"""Hybrid2D: FSDP over ``data`` x tensor-parallel over ``model``
(the MaxText-style 2D default for dense training)."""
from __future__ import annotations

from repro.core.providers.base import Provider, register


class Hybrid2D(Provider):
    name = "hybrid2d"
    flags = {
        "seq_parallel": "shard the residual stream's seq dim over model",
        "shard_vocab": "shard embedding/logits over the model axis",
    }

    def mapping(self, cfg, mesh_axes, flags, segment):
        m = self._common()
        m.update({
            "embed": ["data", None],          # fsdp'd weight dim
            "heads": ["model", None],
            "ffn": ["model", None],
            "experts": ["model", None],
            "expert_ffn": ["model", None],
            "rnn": ["model", None],
            "vocab": "model" if "shard_vocab" in flags else ["data", None],
            "batch": [("pod", "data"), None],
            "seq": "model" if "seq_parallel" in flags else None,
        })
        m.update(self._kv_strategy(cfg, mesh_axes))
        return m


register(Hybrid2D())
