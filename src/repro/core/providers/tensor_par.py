"""TensorPar: Megatron-style tensor parallelism over the ``model`` axis."""
from __future__ import annotations

from repro.core.providers.base import Provider, register


class TensorPar(Provider):
    name = "tensor_par"
    flags = {
        "shard_vocab": "shard embedding/logits over the model axis",
        "seq_parallel": "Megatron-SP: shard the residual stream's seq dim",
    }

    def mapping(self, cfg, mesh_axes, flags, segment):
        m = self._common()
        m.update({
            "heads": ["model", None],
            "ffn": ["model", None],
            "expert_ffn": ["model", None],
            "rnn": ["model", None],
            "experts": None,
            "embed": None,
            "vocab": "model" if "shard_vocab" in flags else None,
            "batch": [("pod", "data"), None],
            "seq": "model" if "seq_parallel" in flags else None,
        })
        m.update(self._kv_strategy(cfg, mesh_axes))
        return m


register(TensorPar())
