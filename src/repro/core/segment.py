"""Fragmentor: enumerate + annotate the parallelizable segments of a model.

ComPar's Fragmentor enumerates loops; here the natural "loop nests" of an
LM are its scan groups (the ``lax.scan`` over homogeneous layers IS a
loop), plus the embedding and head segments.  All structurally identical
layers share one decision — exactly how ComPar treats one loop nest as one
tuning unit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class Segment:
    name: str                       # "embed", "g0", ..., "head"
    kind: str                       # embed | stack | head
    pattern: Tuple[str, ...] = ()   # block kinds for stack segments
    repeats: int = 1

    @property
    def has_moe(self) -> bool:
        return any(k == "attn_moe" for k in self.pattern)

    @property
    def has_attn(self) -> bool:
        return any(k.startswith("attn") for k in self.pattern)

    @property
    def has_recurrent(self) -> bool:
        return any(k in ("rec", "mlstm", "slstm") for k in self.pattern)

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


def fragment(cfg: ArchConfig) -> Tuple[Segment, ...]:
    """Enumerate and annotate all segments (the Fragmentor)."""
    segs = [Segment("embed", "embed")]
    for gi, group in enumerate(cfg.stack_plan()):
        segs.append(Segment(f"g{gi}", "stack", tuple(group.pattern),
                            group.repeats))
    segs.append(Segment("head", "head"))
    return tuple(segs)


def stack_segments(cfg: ArchConfig) -> Tuple[Segment, ...]:
    return tuple(s for s in fragment(cfg) if s.kind == "stack")
