"""Fragmentor: enumerate + annotate the parallelizable segments of a model.

ComPar's Fragmentor enumerates loops; here the natural "loop nests" of an
LM are its scan groups (the ``lax.scan`` over homogeneous layers IS a
loop), plus the embedding and head segments.  All structurally identical
layers share one decision — exactly how ComPar treats one loop nest as one
tuning unit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass
from typing import FrozenSet, Tuple

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class Segment:
    name: str                       # "embed", "g0", ..., "head"
    kind: str                       # embed | stack | head
    pattern: Tuple[str, ...] = ()   # block kinds for stack segments
    repeats: int = 1

    @property
    def has_moe(self) -> bool:
        return any(k == "attn_moe" for k in self.pattern)

    @property
    def has_attn(self) -> bool:
        return any(k.startswith("attn") for k in self.pattern)

    @property
    def has_recurrent(self) -> bool:
        return any(k in ("rec", "mlstm", "slstm") for k in self.pattern)

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats

    # --- wire format (process/remote backend JobSpec) -----------------
    def to_json(self) -> dict:
        return {"name": self.name, "kind": self.kind,
                "pattern": list(self.pattern), "repeats": self.repeats}

    @classmethod
    def from_json(cls, d: dict) -> "Segment":
        return cls(d["name"], d["kind"], tuple(d.get("pattern") or ()),
                   int(d.get("repeats", 1)))

    # --- structural identity ------------------------------------------
    def signature(self, cfg: ArchConfig, shape: ShapeConfig) -> str:
        """Content signature of everything that reaches ``segment_program``
        *besides* the combination: the segment's own structure plus the
        arch/shape fields the program is built from.  Structurally
        identical segments — same pattern/repeats under the same
        arch+shape — share one signature and therefore one score.

        ``cfg.name`` is deliberately excluded: two differently-named
        configs with identical fields build identical programs.
        """
        arch = dataclasses.asdict(cfg)
        arch.pop("name", None)
        blob = json.dumps(
            {"kind": self.kind, "pattern": list(self.pattern),
             "repeats": self.repeats, "arch": arch,
             "shape": {"kind": shape.kind, "seq_len": shape.seq_len,
                       "global_batch": shape.global_batch}},
            sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def relevant_clause_fields(self, shape_kind: str) -> FrozenSet[str]:
        """The SegmentClause fields that can alter this segment's program.

        Deliberately over-inclusive (an extra field only costs cache
        dedup, never correctness): embed/head segments consume no clause
        fields at all; stack segments consume remat/scan_unroll plus the
        per-block-kind kernel knobs.
        """
        if self.kind != "stack":
            return frozenset()
        fields = {"remat", "scan_unroll"}
        if self.has_attn:
            fields |= {"kernel", "block_q", "block_k"}
            if shape_kind == "decode":
                fields |= {"cache_upcast", "decode_shardmap"}
        if self.has_moe:
            fields.add("moe_dispatch")
        if self.has_recurrent:
            fields |= {"kernel", "mlstm_chunk"}
        return frozenset(fields)

    def relevant_knob_fields(self, shape_kind: str) -> FrozenSet[str]:
        """The GlobalKnobs fields that can alter this segment's *program*
        (the knob analogue of :meth:`relevant_clause_fields`).

        ``microbatches`` and ``donate`` reshape the built/jitted train
        program (gradient-accumulation scan; buffer donation at jit) on
        every segment kind — training wraps them all in a backward pass.
        Inference shapes (prefill/decode) have neither, so no knob
        reaches their programs and sweeping any knob is free there.
        ``opt_state_dtype`` never appears: the optimizer update is not
        part of any segment program, so sweeping it adds zero compiles on
        every shape — knob points differing only in it share one
        effective cid per segment.
        """
        if shape_kind != "train":
            return frozenset()
        return frozenset({"microbatches", "donate"})


def fragment(cfg: ArchConfig) -> Tuple[Segment, ...]:
    """Enumerate and annotate all segments (the Fragmentor)."""
    segs = [Segment("embed", "embed")]
    for gi, group in enumerate(cfg.stack_plan()):
        segs.append(Segment(f"g{gi}", "stack", tuple(group.pattern),
                            group.repeats))
    segs.append(Segment("head", "head"))
    return tuple(segs)


def stack_segments(cfg: ArchConfig) -> Tuple[Segment, ...]:
    return tuple(s for s in fragment(cfg) if s.kind == "stack")
