"""Timer: per-segment cost attribution.

ComPar's Timer wraps every enumerated loop with wall-clock probes; the
Executor then logs total + per-loop times.  ComParX builds, per segment, a
standalone jitted program (with the segment's own sharding rules applied)
and derives its cost from the compiled artifact — or from wall-clock when
a real executor runs it.  Training shapes measure forward+backward.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from typing import Optional

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import Combination, GlobalKnobs
from repro.core.plan import dp_shards
from repro.core.providers import get_provider
from repro.core.segment import Segment
from repro.models.context import ModelContext
from repro.models.loss import softmax_xent
from repro.models.model import (SEG_EMBED, cache_specs, embed_tokens,
                                lm_head, model_specs, _run_group)
from repro.models.params import abstract_params, param_pspecs
from repro.runtime.sharding import Rules


def _ctx_for(cfg, mesh, combo: Combination, seg: Segment,
             interpret: bool = True) -> ModelContext:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if mesh is not None else {}
    mapping = get_provider(combo.provider).mapping(
        cfg, axis_sizes, combo.flags, seg)
    return ModelContext(rules=Rules(mapping, mesh), clause=combo.clause,
                        moe_groups=dp_shards(mesh), interpret=interpret)


def segment_program(cfg: ArchConfig, shape: ShapeConfig, seg: Segment,
                    combo: Combination, mesh, *, interpret: bool = True,
                    knobs: Optional[GlobalKnobs] = None
                    ) -> Tuple[Callable, Tuple, Dict]:
    """Build (fn, abstract_args, arg_shardings) for one segment.

    ``fn`` captures the segment's compute under the combination; for
    training shapes it includes the backward pass, and — when ``knobs``
    are given — the gradient-accumulation microbatch scan (the per-step
    batch is reshaped to ``(microbatches, B/microbatches, ...)`` and the
    fwd+bwd scanned over the slices, mirroring ``train.step``).  Only the
    knob fields in ``Segment.relevant_knob_fields`` reach the program;
    inference shapes ignore knobs entirely.
    """
    ctx = _ctx_for(cfg, mesh, combo, seg, interpret)
    specs = model_specs(cfg)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype("int32")
    dt = jnp.dtype(cfg.dtype)
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    mb = knobs.microbatches if (train and knobs is not None) else 1

    def shard(ax, shp):
        if mesh is None:
            return None
        return NamedSharding(mesh, ctx.rules.pspec(ax, shp))

    x_shape = (B, cfg.d_model) if decode else (B, S, cfg.d_model)
    x_axes = ("batch", "embed") if decode else ("batch", "seq", "embed")
    x_sds = jax.ShapeDtypeStruct(x_shape, dt)
    x_sh = shard(x_axes, x_shape)

    if seg.kind == "embed":
        p_abs = abstract_params({SEG_EMBED: specs[SEG_EMBED]})
        p_sh = _pshard({SEG_EMBED: specs[SEG_EMBED]}, ctx.rules, mesh)
        tok_shape = (B,) if decode else (B, S)
        tok = jax.ShapeDtypeStruct(tok_shape, i32)

        def fn(p, tokens):
            return embed_tokens(p, tokens, cfg, ctx)
        if train:
            fn = _with_microbatches(_with_bwd(fn, argnums=(0,)), mb)
        return fn, (p_abs, tok), (p_sh, shard(("batch", "seq"), tok_shape))

    if seg.kind == "head":
        need = {"head": specs["head"]}
        if cfg.tie_embeddings:
            need[SEG_EMBED] = specs[SEG_EMBED]
        p_abs = abstract_params(need)
        p_sh = _pshard(need, ctx.rules, mesh)

        def fn(p, x):
            logits = lm_head(p, x, cfg, ctx)
            tgt = jnp.zeros(logits.shape[:-1], i32)
            loss, _ = softmax_xent(logits, tgt)
            return loss
        if train:
            fn = _with_microbatches(
                _with_bwd(fn, argnums=(0, 1), scalar=True), mb)
        return fn, (p_abs, x_sds), (p_sh, x_sh)

    # --- stack segment -------------------------------------------------
    gname = seg.name
    p_abs = abstract_params(specs[gname])
    p_sh = _pshard(specs[gname], ctx.rules, mesh)
    group = [g for i, g in enumerate(cfg.stack_plan())
             if f"g{i}" == gname][0]

    if decode:
        from repro.serve.step import cache_axes
        cspecs = cache_specs(cfg, B, shape.seq_len)[gname]
        caxes = cache_axes(cfg)[gname]
        c_sh = jax.tree.map(
            lambda a, s: shard(a, s.shape), caxes, cspecs,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t)) \
            if mesh is not None else None
        pos = jax.ShapeDtypeStruct((), i32)

        def fn(p, caches, x, pos):
            from repro.models.blocks import block_decode

            def superblock(x, lp, lc):
                nc = {}
                for j, kind in enumerate(group.pattern):
                    x, c = block_decode(kind, lp[f"b{j}"], x, lc[f"b{j}"],
                                        pos, cfg, ctx)
                    nc[f"b{j}"] = c
                return x, nc
            if group.repeats == 1:
                return superblock(x, p, caches)
            return jax.lax.scan(
                lambda x, pc: superblock(x, *pc), x, (p, caches))
        return fn, (p_abs, cspecs, x_sds, pos), (p_sh, c_sh, x_sh, None)

    def fn(p, x):
        positions = jnp.arange(S, dtype=i32)
        y, aux = _run_group(x, p, group, cfg, ctx, positions)
        return y
    if train:
        fn = _with_microbatches(_with_bwd(fn, argnums=(0, 1)), mb)
    return fn, (p_abs, x_sds), (p_sh, x_sh)


def _pshard(spec_tree, rules: Rules, mesh):
    if mesh is None:
        return None
    ps = param_pspecs(spec_tree, rules)
    from jax.sharding import PartitionSpec
    return jax.tree.map(lambda s: NamedSharding(mesh, s), ps,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def _with_microbatches(fn, mb: int):
    """Gradient-accumulation analogue for segment scoring: split the batch
    (arg 1; arg 0 is always the segment's params) into ``mb`` slices,
    scan the fwd+bwd ``fn`` over them and average the grads — the same
    program shape ``train.step`` builds, so a swept microbatch count is
    scored with the compute/memory profile it will actually run with.
    Summing (rather than stacking) the data-side grads is fine here: the
    wrapper exists to shape the compiled program for cost attribution,
    not to train."""
    if mb <= 1:
        return fn

    @functools.wraps(fn)
    def wrapped(p, x):
        if x.shape[0] % mb:
            raise ValueError(
                f"global_batch {x.shape[0]} not divisible by "
                f"microbatches={mb}")
        xs = x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
        acc0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            jax.eval_shape(fn, p, xs[0]))

        def step(acc, xi):
            return jax.tree.map(jnp.add, acc, fn(p, xi)), None

        acc, _ = jax.lax.scan(step, acc0, xs)
        return jax.tree.map(lambda g: g / mb, acc)
    return wrapped


def _with_bwd(fn, argnums=(0,), scalar: bool = False):
    """Wrap a segment fn so its cost includes the backward pass."""
    @functools.wraps(fn)
    def wrapped(*args):
        def scalar_loss(*a):
            out = fn(*a)
            if scalar:
                return out
            return jnp.sum(jnp.square(out.astype(jnp.float32)))
        return jax.grad(scalar_loss, argnums=argnums)(*args)
    return wrapped
