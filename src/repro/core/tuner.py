"""ComParX tuner: the paper's end-to-end workflow (Fig. 1).

Fragmentor -> Combinator (-> DB register) -> Parallelizer+Executor per
(combination, knob point) (-> DB record, Continue-mode resumable) ->
black-box validation -> Optimal Plan Generator -> fused Plan whose
``knobs`` are the joint argmin over the swept GlobalKnobs grid
(``sweep(global_space=...)`` — the paper's RTL-routine axis).

The sweep execution core is the three-stage pipeline of
``repro.core.backends`` (see docs/sweep_engine.md):

* **Scheduler** — groups (segment, combination) rows that resolve to the
  *same program* (structural score sharing), resolves whole groups from
  the persistent cross-project ``score_cache``, and orders the remaining
  unique programs cheapest-lower-bound-first.
* **ScoringBackend** — scores unique programs: ``thread`` (PR-1
  semantics; soft off-main-thread deadline), ``sequential`` (one worker,
  no pool), ``process`` (spawned workers; true parallel tracing past
  the GIL and a *hard* kill-based timeout with requeue-once-then-fail),
  or ``remote`` (ship jobs to a sweep scoring server —
  ``sweep(remote_url=...)`` — which resolves them against ITS shared
  score cache first: cross-host amortization).
* **Recorder** — fans outcomes back out to member rows, keeps the
  report accounting, applies the cache policy (transient outcomes are
  never cached), and writes batched transactions.

Exact lower-bound pruning (never changes the argmin) runs inside the
backend against shared incumbents.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.backends import Recorder, Scheduler, make_backend
from repro.core.combinator import (Combination, GlobalKnobs, SweepSpec,
                                   enumerate_combinations, global_grid,
                                   paper_combination_count, row_cid,
                                   swept_knob_fields)
from repro.core.cost_model import CostTerms
from repro.core.db import SweepDB
from repro.core.executor import (DryRunExecutor, ParallelSweepRunner,  # noqa: F401  (ParallelSweepRunner re-exported for spies/back-compat)
                                 SweepJob, WallClockExecutor)
from repro.core.fusion import best_uniform, fuse, fuse_joint  # noqa: F401  (fuse re-exported)
from repro.core.meshspec import MeshSpec, as_mesh_point, cached_mesh
from repro.core.plan import Plan
from repro.core.providers import all_providers, get_provider
from repro.core.segment import Segment, fragment

log = logging.getLogger("repro.tuner")


@dataclass
class SweepReport:
    project: str
    n_combinations: int     # realized registered rows (incl. the knob axis)
    n_done: int = 0
    n_failed: int = 0
    n_invalid: int = 0
    n_pruned: int = 0       # rows skipped by the exact lower-bound prune
    n_scored: int = 0       # programs that actually compiled+analyzed
    n_cached: int = 0       # rows served from the persistent score cache
    n_shared: int = 0       # rows that shared an in-run compiled score
    n_transient: int = 0    # rows failed by deadline/crash (retryable)
    n_static: int = 0       # rows rejected by the static analyzer before
                            # dispatch (static_checks="strict")
    n_inapplicable: int = 0  # (segment, combination) pairs dropped because
                             # the provider is inapplicable to the segment
                             # (counted once, before the knob/mesh axes)
    n_knob_points: int = 1  # GlobalKnobs points swept (the RTL axis)
    n_mesh_points: int = 1  # mesh/topology points swept (the mesh axis)
    paper_count: int = 0    # the paper's formula, an upper bound
    elapsed_s: float = 0.0
    #: degraded-mode accounting — a sweep that limped home must say so
    n_fallback_local: int = 0       # rows re-scored locally after the
                                    # remote retry budget ran out
    n_transient_retried: int = 0    # extra dispatches spent on transient
                                    # recovery (requeues + retry rounds)
    #: failure-kind histogram over FAILED rows ("deadline", "crash",
    #: "mesh", "unreachable", "server", "deterministic", "transient")
    failure_kinds: Dict[str, int] = field(default_factory=dict)
    #: per-rule histogram over statically diagnosed rows (strict AND
    #: warn modes; one count per row per distinct rule) — see
    #: repro.analysis for the rule ids
    static_rules: Dict[str, int] = field(default_factory=dict)
    #: the winning (mesh, knob) point's per-segment valid rows
    per_segment: Dict[str, List[Tuple[Combination, CostTerms]]] = \
        field(default_factory=dict)
    #: knobs.key() -> fused predicted total, every fusable knob point
    #: (of the winning mesh point, when the mesh is swept)
    per_knob_total_s: Dict[str, float] = field(default_factory=dict)
    #: mesh.key() -> fused predicted total, every fusable mesh point
    per_mesh_total_s: Dict[str, float] = field(default_factory=dict)
    #: segment kind -> {"n", "mean", "max"} of bound/measured over done
    #: rows — the drift observability for the calibrated machine model
    #: (a ratio > 1 means the certificate broke: see audit_soundness)
    bound_tightness: Dict[str, Dict[str, float]] = field(default_factory=dict)
    #: the inner kernel sweep's observability (``sweep(kernel_space=...)``):
    #: variants enumerated/timed/cache-hit/failed, top_k, per-op best
    #: schedule, per-segment kept counts.  None = no kernel axis.
    kernel_tuning: Optional[Dict] = None

    def summary(self) -> str:
        s = (f"project={self.project} knob_points={self.n_knob_points} "
             f"mesh_points={self.n_mesh_points} "
             f"done={self.n_done} failed={self.n_failed} "
             f"invalid={self.n_invalid} pruned={self.n_pruned} "
             f"scored={self.n_scored} cached={self.n_cached} "
             f"shared={self.n_shared} transient={self.n_transient} "
             f"realized={self.n_combinations} "
             f"paper_formula_upper_bound={self.paper_count} "
             f"elapsed={self.elapsed_s:.1f}s")
        if self.n_static or self.static_rules:
            s += f" static={self.n_static}"
            if self.static_rules:
                rules = ",".join(f"{k}:{v}" for k, v in
                                 sorted(self.static_rules.items()))
                s += f"[{rules}]"
        if self.n_inapplicable:
            s += f" inapplicable={self.n_inapplicable}"
        if self.n_transient_retried:
            s += f" transient_retried={self.n_transient_retried}"
        if self.n_fallback_local:
            s += f" fallback_local={self.n_fallback_local}"
        if self.failure_kinds:
            kinds = ",".join(f"{k}:{v}" for k, v in
                             sorted(self.failure_kinds.items()))
            s += f" failure_kinds={kinds}"
        if self.bound_tightness:
            tight = ",".join(
                f"{k}:mean={v['mean']:.2f}/max={v['max']:.2f}(n={v['n']})"
                for k, v in sorted(self.bound_tightness.items()))
            s += f" bound_tightness={tight}"
        if self.kernel_tuning:
            kt = self.kernel_tuning
            s += (f" kernel_tuning=variants:{kt['n_variants']},"
                  f"timed:{kt['n_timed']},cached:{kt['n_cached']},"
                  f"failed:{kt['n_failed']},top_k:{kt['top_k']}")
        return s


@dataclass(frozen=True)
class BackendOptions:
    """``sweep()``'s scoring-backend kwargs as one typed value.

    ``sweep(backend=BackendOptions(...))`` — the bare kwargs
    (``workers=``, ``remote_url=``, ...) still work and mean exactly the
    same thing; passing a bundle AND a non-default bare kwarg of the
    same group is a ValueError, never a silent override."""
    backend: str = "thread"
    workers: int = 1
    remote_url: Optional[str] = None
    remote_token: Optional[str] = None
    fallback: Optional[str] = None
    retry: Optional[object] = None          # backends.RetryPolicy
    transient_retries: Optional[int] = None


@dataclass(frozen=True)
class SearchOptions:
    """``sweep()``'s search-strategy kwargs as one typed value
    (``sweep(search=SearchOptions(...))``); same conflict contract as
    :class:`BackendOptions`."""
    prune: bool = False
    prune_margin: float = 0.1
    static_checks: str = "warn"
    kernel_space: Optional[object] = None   # "auto" | {field: values}
    kernel_top_k: int = 2
    use_cache: bool = True
    share_scores: bool = True
    record_batch: int = 64


def _unbundle(bundle, bare: Dict[str, Tuple], kind: str) -> List:
    """Explode a kwarg bundle, refusing non-default bare twins."""
    clash = [k for k, (v, d) in bare.items() if v is not d and v != d]
    if clash:
        raise ValueError(
            f"{kind} conflicts with bare kwarg(s) {sorted(clash)}: pass "
            f"the value inside the bundle or drop the bundle")
    return [getattr(bundle, f) for f in bundle.__dataclass_fields__]


class ComParTuner:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, mesh=None, *,
                 db: Optional[SweepDB] = None, project: Optional[str] = None,
                 mode: str = "new", executor: str = "dryrun",
                 machine=None, registry=None,
                 validate: bool = False, timeout_s: Optional[int] = 300):
        self.cfg = cfg
        self.shape = shape
        # a declarative MeshSpec is accepted wherever a live mesh is:
        # materialized here once against local devices
        self.mesh = cached_mesh(mesh) if isinstance(mesh, MeshSpec) else mesh
        self.db = db or SweepDB(":memory:")
        name = project or f"{cfg.name}-{shape.name}"
        self.project = self.db.open_project(
            name, mode, {"arch": cfg.name, "shape": shape.name})
        # ``machine``: the dryrun scorer's hardware model — None (the
        # built-in v5e constants), "auto" (calibrate this host or load
        # its cached profile from the DB's machine_cache), a
        # MachineProfile, or a Hardware.  The calibrated view's name
        # lands in the executor cache_tag, so calibrated and constant
        # scores never share cache rows; bounds divide by the same view
        # (Scheduler reads executor.hw), so pruning stays exact.
        if executor == "dryrun":
            hw = None
            if machine is not None:
                from repro.core.machine import resolve_machine
                hw = resolve_machine(machine, self.db)
            self.executor = DryRunExecutor(
                self.mesh, timeout_s=timeout_s,
                **({"hw": hw} if hw is not None else {}))
        elif executor == "wallclock":
            if machine is not None:
                log.warning("machine= ignored: wallclock scores are "
                            "measured, not modeled")
            self.executor = WallClockExecutor(self.mesh, timeout_s=timeout_s)
        else:
            raise ValueError(executor)
        # ``registry``: where the fused plan of every ``sweep()`` is
        # persisted for the serving side (repro.serve) — None (off),
        # True (a PlanRegistry in THIS tuner's DB: plans beside the
        # scores that produced them), a PlanRegistry, or a DB path.
        self.registry = None
        if registry is not None and registry is not False:
            from repro.serve.registry import PlanRegistry
            if registry is True:
                self.registry = PlanRegistry(self.db)
            elif hasattr(registry, "register") and hasattr(registry,
                                                           "lookup"):
                # duck-typed, not isinstance: `python -m` runs modules
                # under __main__, which forks the class object
                self.registry = registry
            else:
                self.registry = PlanRegistry(registry)
        self.validate = validate
        #: cached ScoringBackends (warm process pools) — see _engine()
        self._engines: Dict[Tuple, object] = {}
        #: the latest sweep's kernel-autotuner verdict (None = no kernel
        #: axis) — _bound_tightness/audit_soundness recompute bounds with
        #: the same per-schedule floors the Scheduler stamped on jobs
        self._kernel_tuning = None

    # ------------------------------------------------------------------
    def sweep(self, providers: Optional[Sequence[str]] = None,
              clause_space=None, *,
              spec: Optional[SweepSpec] = None,
              budget: Optional[int] = None,
              knobs: GlobalKnobs = GlobalKnobs(),
              global_space: Optional[Dict[str, Tuple]] = None,
              mesh_space: Optional[Sequence] = None,
              boundary_costs: bool = False,
              max_flags: Optional[int] = None,
              backend="thread",
              search: Optional[SearchOptions] = None,
              workers: int = 1,
              remote_url: Optional[str] = None,
              remote_token: Optional[str] = None,
              fallback: Optional[str] = None,
              retry=None,
              transient_retries: Optional[int] = None,
              kernel_space=None, kernel_top_k: int = 2,
              static_checks: str = "warn",
              prune: bool = False, prune_margin: float = 0.1,
              use_cache: bool = True, share_scores: bool = True,
              record_batch: int = 64) -> Tuple[Plan, SweepReport]:
        """Run the sweep.  Engine knobs (see docs/sweep_engine.md):

        ``spec``          a :class:`~repro.core.combinator.SweepSpec`
                          carrying the whole search space (providers +
                          clause/global/mesh/kernel axes) as one typed
                          value — what :func:`load_sweep_json` returns.
                          Conflicts with the bare axis kwargs it covers
                          (``providers``/``clause_space``/
                          ``global_space``/``mesh_space``/
                          ``kernel_space``): passing both is a
                          ValueError.
        ``search``        a :class:`SearchOptions` bundling the
                          search-strategy kwargs (prune/static_checks/
                          kernel axis/cache policy); ``backend`` also
                          accepts a :class:`BackendOptions` bundling the
                          scoring-backend kwargs.  Bare kwargs still
                          work and are normalized to the same values —
                          a bundle plus a non-default bare twin raises.
        ``global_space``  GlobalKnobs grid to sweep as the outer axis
                          (the paper's RTL-routine dimension), e.g.
                          ``{"microbatches": (1, 2)}`` — unlisted fields
                          stay at their defaults.  The returned plan's
                          ``knobs`` are the joint argmin across the
                          grid.  Default ``None`` = today's single fixed
                          point (the ``knobs`` argument, which is
                          otherwise ignored).  The grid is not
                          ``budget``-sampled.
        ``mesh_space``    mesh/topology points swept as a second outer
                          axis: a list of ``MeshSpec`` | ``None`` (the
                          local point) | ``{"axis": size}`` dicts | live
                          meshes.  The returned ``plan.mesh`` is CHOSEN
                          by the joint argmin over
                          (segment, combination, knobs, mesh).  Default
                          ``None`` = the mesh is not swept (the
                          constructor's fixed mesh applies); when given,
                          the constructor mesh is *not* implicitly a
                          point — list it if you want it raced.
        ``backend``       scoring backend: ``thread`` (default) |
                          ``sequential`` | ``process`` | ``remote``
        ``workers``       workers scoring unique programs (threads or
                          spawned processes, per ``backend``; the remote
                          backend's workers live server-side)
        ``remote_url``    sweep scoring server URL (``backends/server.py``);
                          implies ``backend="remote"``.  Jobs are shipped
                          as JSON and resolved against the *server's*
                          score cache first — cross-host score sharing.
        ``remote_token``  shared-secret bearer token for a ``--token``
                          server (401 without it is a protocol error,
                          never retried)
        ``fallback``      local backend name (``thread`` | ``sequential``
                          | ``process``) that re-scores, in the same
                          run, jobs the remote backend failed
                          transiently (outage past the retry budget) —
                          the degraded-mode path; counted loudly in
                          ``SweepReport.n_fallback_local``
        ``retry``         a :class:`~repro.core.backends.RetryPolicy`
                          overriding the pipeline's retry contract
                          (request budget/backoff, per-job dispatch
                          attempts, scheduler retry rounds)
        ``transient_retries``  bounded Scheduler-level rounds re-running
                          transient failures in-sweep before they are
                          recorded (default: the retry policy's
                          ``sweep_retries``, 1)
        ``kernel_space``  the hierarchical kernel axis: ``"auto"`` (the
                          built-in tile/variant grid) or a
                          ``{field: values}`` grid over the kernel
                          schedule fields (``kernel``/``block_q``/
                          ``block_k``/``mlstm_chunk``).  The kernel
                          autotuner times every (op, schedule) variant
                          in isolation first (``kernel_cache``-resolved:
                          repeat sweeps re-benchmark nothing), then the
                          outer cross-product carries only the
                          ``kernel_top_k`` cheapest schedules per
                          segment — a T-schedule grid adds at most k
                          combos per affected segment instead of xT
                          compiles.  Kernel-space fields override the
                          same fields of ``clause_space`` in the
                          enumerated grid.  Default ``None`` = no inner
                          sweep (today's flat behavior).
        ``kernel_top_k``  surviving schedules per segment
                          (``>= len(grid)`` keeps everything: the sweep
                          is then byte-identical to an exhaustive clause
                          sweep over the merged space)
        ``static_checks`` the static validity analyzer
                          (``repro.analysis``): ``"warn"`` (default —
                          lint every point, report the per-rule
                          histogram in ``SweepReport.static_rules``,
                          dispatch everything), ``"strict"`` (also
                          settle ``error``-diagnosed rows as
                          ``"static"`` before they become JobSpecs —
                          sound: every dropped point provably fails
                          when compiled, so the fused plan is
                          byte-identical to an unlinted sweep), or
                          ``"off"`` (no lint at all).  Static rows are
                          never written to ``score_cache``.
        ``prune``         exact lower-bound pruning on/off
        ``prune_margin``  relative headroom the bound must clear
        ``use_cache``     persistent structural score cache on/off
        ``share_scores``  group structurally identical rows into one
                          compile (off = one compile per row, the
                          pre-engine behavior — benchmark baseline)
        ``record_batch``  DB rows per write transaction
        """
        t0 = time.time()
        # normalize the typed kwarg bundles first (backend, then search,
        # then spec), so a spec/bundle field colliding with a bare kwarg
        # is caught no matter which side carried it
        if isinstance(backend, BackendOptions):
            (backend, workers, remote_url, remote_token, fallback, retry,
             transient_retries) = _unbundle(
                backend,
                {"workers": (workers, 1), "remote_url": (remote_url, None),
                 "remote_token": (remote_token, None),
                 "fallback": (fallback, None), "retry": (retry, None),
                 "transient_retries": (transient_retries, None)},
                "BackendOptions")
        if search is not None:
            if not isinstance(search, SearchOptions):
                raise ValueError(f"search= takes a SearchOptions, got "
                                 f"{type(search).__name__}")
            (prune, prune_margin, static_checks, kernel_space,
             kernel_top_k, use_cache, share_scores, record_batch) = \
                _unbundle(
                    search,
                    {"prune": (prune, False),
                     "prune_margin": (prune_margin, 0.1),
                     "static_checks": (static_checks, "warn"),
                     "kernel_space": (kernel_space, None),
                     "kernel_top_k": (kernel_top_k, 2),
                     "use_cache": (use_cache, True),
                     "share_scores": (share_scores, True),
                     "record_batch": (record_batch, 64)},
                    "SearchOptions")
        if spec is not None:
            if not isinstance(spec, SweepSpec):
                raise ValueError(f"spec= takes a SweepSpec, got "
                                 f"{type(spec).__name__}")
            clash = [k for k, v in
                     {"providers": providers, "clause_space": clause_space,
                      "global_space": global_space,
                      "mesh_space": mesh_space,
                      "kernel_space": kernel_space}.items()
                     if v is not None]
            if clash:
                raise ValueError(
                    f"spec= conflicts with bare kwarg(s) {sorted(clash)}: "
                    f"the SweepSpec already carries those axes")
            providers = list(spec.providers) or None
            clause_space = spec.clauses
            global_space = spec.globals
            mesh_space = list(spec.meshes) if spec.meshes is not None \
                else None
            kernel_space = spec.kernel_space
        points = global_grid(global_space) if global_space is not None \
            else [knobs]
        if isinstance(mesh_space, str):
            if mesh_space != "auto":
                raise ValueError(f"mesh_space={mesh_space!r}: the only "
                                 f"string value is 'auto'")
            from repro.core.meshspec import default_mesh_space
            mesh_space = default_mesh_space()
        mesh_swept = mesh_space is not None
        mpoints: Optional[List[MeshSpec]] = None
        if mesh_swept:
            # normalize + dedupe by content: the same topology listed
            # twice would register colliding rows and double-count points
            mpoints, seen = [], set()
            for m in mesh_space:
                mp = as_mesh_point(m)
                if mp.mid not in seen:
                    seen.add(mp.mid)
                    mpoints.append(mp)
            if not mpoints:
                raise ValueError("mesh_space is empty")
            if self.mesh is not None:
                log.info("mesh_space sweeps its own points; the fixed "
                         "constructor mesh is not implicitly included")
        # prune + boundary_costs compose exactly now: the Scheduler
        # stamps every job with the Viterbi pruning allowance
        # (JobSpec.slack_s = (n_segs-1) * max single boundary cost), so
        # a pruned combination provably cannot win any chain either —
        # see IncumbentTracker.pruned and fusion.max_boundary_cost_s.
        if remote_url is not None:
            backend = "remote"
        if backend == "remote" and not remote_url:
            raise ValueError("backend='remote' needs remote_url "
                             "(the sweep scoring server URL)")
        if fallback is not None and backend != "remote":
            raise ValueError("fallback= is the remote backend's degraded "
                             "mode; it needs remote_url/backend='remote'")
        if workers > 1 and not getattr(self.executor, "parallel_safe", True):
            log.warning("workers=%d -> 1: %s timings would contend on the "
                        "device", workers, type(self.executor).__name__)
            workers = 1
        if prune and not hasattr(self.executor, "hw"):
            # the bound divides by the analytic hw model's peak; against an
            # executor measuring real wall seconds on unknown hardware the
            # certificate (bound <= score) no longer holds
            log.warning("prune disabled: %s has no hardware model",
                        type(self.executor).__name__)
            prune = False
        providers = list(providers or all_providers())
        segs = fragment(self.cfg)

        # Hierarchical kernel axis: run the inner (op, schedule) sweep
        # first, then enumerate the OUTER space over the merged grid and
        # filter each segment down to its top-k surviving schedules.
        # Filtering (instead of nested expansion) preserves enumeration
        # order, so kernel_top_k >= len(grid) registers rows in exactly
        # the order an exhaustive clause sweep would — argmin tie-breaks,
        # and therefore fused plans, stay byte-identical.
        tuning = None
        space = clause_space
        if kernel_space is not None:
            from repro.kernels.autotune import (DEFAULT_KERNEL_SPACE,
                                                tune_segments)
            if isinstance(kernel_space, str):
                if kernel_space != "auto":
                    raise ValueError(f"kernel_space={kernel_space!r}: the "
                                     f"only string value is 'auto'")
                kernel_space = DEFAULT_KERNEL_SPACE
            kspace = {k: tuple(v) for k, v in kernel_space.items()}
            from repro.core.combinator import DEFAULT_CLAUSE_SPACE
            space = dict(clause_space or DEFAULT_CLAUSE_SPACE)
            space.update(kspace)
            tuning = tune_segments(self.db, self.cfg, self.shape, segs,
                                   space, self.executor,
                                   top_k=kernel_top_k, use_cache=use_cache)
            rep_kernel = tuning.report
        self._kernel_tuning = tuning

        combos = enumerate_combinations(providers, space,
                                        budget=budget, max_flags=max_flags)
        rep = SweepReport(
            self.project, n_combinations=0, n_knob_points=len(points),
            n_mesh_points=len(mpoints) if mesh_swept else 1,
            paper_count=paper_combination_count(
                [len(get_provider(p).flags) for p in providers],
                # charge the formula's rtl term for what is actually
                # swept, not the field count of a fixed knobs instance
                n_rtl=len(swept_knob_fields(global_space)),
                n_d=len(space or {}) or 6))
        if tuning is not None:
            rep.kernel_tuning = rep_kernel

        # Combinator: register every (segment, combination, knob point,
        # mesh point), one transaction.  Unswept mesh = None (bare row
        # ids: pre-mesh projects resume unchanged).  Inapplicable
        # (provider, segment) pairs are counted, not silently dropped —
        # sweep accounting must be exact against paper_combination_count.
        per_seg_combos: Dict[str, List[Combination]] = {}
        for seg in segs:
            kept: List[Combination] = []
            for c in combos:
                if not get_provider(c.provider).applicable(self.cfg, seg):
                    rep.n_inapplicable += 1
                    continue
                if tuning is not None and not tuning.keeps(seg.name,
                                                           c.clause):
                    continue
                kept.append(c)
            per_seg_combos[seg.name] = kept
        reg: List[Tuple] = []
        for mp in (mpoints if mesh_swept else [None]):
            for kn in points:
                for seg in segs:
                    reg.extend((seg.name, c, kn, mp)
                               for c in per_seg_combos[seg.name])
        rep.n_combinations = len(reg)
        self.db.register_many(self.project, reg)

        self._execute(segs, per_seg_combos, points, rep,
                      mesh_points=mpoints, kernel_tuning=tuning,
                      static_checks=static_checks,
                      backend=backend, workers=workers,
                      remote_url=remote_url, remote_token=remote_token,
                      fallback=fallback, retry=retry,
                      transient_retries=transient_retries, prune=prune,
                      prune_margin=prune_margin, use_cache=use_cache,
                      share_scores=share_scores, record_batch=record_batch,
                      boundary_slack=prune and boundary_costs)

        # collect valid results per (mesh point, knob point, segment)
        by_rid = {(r["segment"], r["cid"]): r
                  for r in self.db.results(self.project)}

        def knob_table(mp):
            per_knob: Dict[str, Dict[str, List[Tuple[Combination,
                                                     CostTerms]]]] = {}
            for kn in points:
                table = per_knob.setdefault(kn.kid, {})
                for seg in segs:
                    good = table.setdefault(seg.name, [])
                    for c in per_seg_combos[seg.name]:
                        r = by_rid.get((seg.name, row_cid(c, kn, mp)))
                        if r is not None and r["status"] == "done" \
                                and r["cost"]:
                            good.append((c, CostTerms.from_dict(r["cost"])))
            return per_knob

        counts = self.db.done_count(self.project)
        rep.n_done = counts.get("done", 0)
        rep.n_failed = counts.get("failed", 0)
        rep.n_invalid = counts.get("invalid", 0)
        rep.n_pruned = counts.get("pruned", 0)
        rep.n_static = counts.get("static", 0)
        rep.bound_tightness, violations = self._bound_tightness()
        if violations:
            # should be impossible (the bound is certified); seeing this
            # in a summary means a floor overshoots — fix it before
            # trusting prune=True
            log.warning("bound soundness violated on %d done row(s): %s",
                        len(violations), violations[:3])

        if mesh_swept:
            per_mesh = {mp.mid: knob_table(mp) for mp in mpoints}
            plan = fuse_joint(self.cfg, self.shape, None, per_mesh, points,
                              boundary_costs=boundary_costs,
                              mesh_points=mpoints)
            rep.per_segment = per_mesh[plan.mesh.mid][plan.knobs.kid]
            rep.per_mesh_total_s = dict(plan.meta["per_mesh_total_s"])
        else:
            per_knob = knob_table(None)
            plan = fuse_joint(self.cfg, self.shape, self.mesh, per_knob,
                              points, boundary_costs=boundary_costs)
            rep.per_segment = per_knob[plan.knobs.kid]
        plan.meta["project"] = self.project
        rep.per_knob_total_s = dict(plan.meta["per_knob_total_s"])
        rep.elapsed_s = time.time() - t0
        if self.registry is not None:
            # plans are keyed by what they were tuned FOR: the plan's
            # chosen mesh when the mesh was swept, the fixed one else
            self.registry.register(
                self.cfg, self.shape, plan, report=rep,
                mesh=plan.mesh if plan.mesh is not None else self.mesh,
                cache_tag=self.executor.cache_tag)
        log.info(rep.summary())
        return plan, rep

    # ------------------------------------------------------------------
    def _bound_tightness(self):
        """Recompute ``combo_lower_bound`` for every ``done`` row of
        this project and compare against the recorded score.

        Returns ``(table, violations)``: a per-segment-kind
        ``{"n", "mean", "max"}`` table of bound/measured ratios (the
        SweepReport's drift observability) and the rows where the bound
        exceeded the measurement — which the certificate says must be
        empty.  Cheap: no compiles, one DB scan.
        """
        from repro.core.cost_model import V5E, combo_lower_bound
        hw = getattr(self.executor, "hw", V5E)
        fixed_chips = getattr(self.executor, "n_chips", 1)
        fixed_axes = dict(zip(self.mesh.axis_names,
                              self.mesh.devices.shape)) \
            if self.mesh is not None else None
        segs = {s.name: s for s in fragment(self.cfg)}
        stats: Dict[str, Dict[str, float]] = {}
        violations = []
        for r in self.db.results(self.project):
            if r["status"] != "done" or not r["cost"]:
                continue
            seg = segs.get(r["segment"])
            if seg is None:
                continue
            mesh = r["mesh"]
            # rows recorded by a pre-kernel-axis sweep of the same
            # project project to unmeasured schedules -> floor 0.0
            kflops = self._kernel_tuning.floor_flops(
                r["segment"], r["combo"].clause) \
                if self._kernel_tuning is not None else 0.0
            bound = combo_lower_bound(
                self.cfg, self.shape, seg, r["combo"],
                mesh.n_devices if mesh is not None else fixed_chips, hw,
                knobs=r["knobs"],
                mesh_axes=mesh.axis_sizes() if mesh is not None
                else fixed_axes, kernel_flops=kflops)
            total = CostTerms.from_dict(r["cost"]).total_s
            if total <= 0.0:
                continue
            ratio = bound / total
            st = stats.setdefault(seg.kind, {"n": 0, "sum": 0.0, "max": 0.0})
            st["n"] += 1
            st["sum"] += ratio
            st["max"] = max(st["max"], ratio)
            if bound > total * (1.0 + 1e-9):
                violations.append((r["segment"], r["cid"], bound, total))
        table = {k: {"n": int(v["n"]), "mean": v["sum"] / v["n"],
                     "max": v["max"]} for k, v in stats.items() if v["n"]}
        return table, violations

    def audit_soundness(self) -> Dict[str, Dict[str, float]]:
        """Assert ``combo_lower_bound <= measured total_s`` for every
        ``done`` row in this project; returns the per-kind tightness
        table on success.

        With the dryrun executor this checks the actual pruning
        certificate (bound and score share ``executor.hw``).  With a
        wallclock executor the bound models different units than the
        measurement, so the check is skipped for assertion purposes
        (pruning is force-disabled there anyway) and only the table is
        returned.
        """
        table, violations = self._bound_tightness()
        if violations and hasattr(self.executor, "hw"):
            lines = "; ".join(
                f"{seg}/{cid}: bound={b:.3e} > measured={t:.3e}"
                for seg, cid, b, t in violations[:10])
            raise AssertionError(
                f"combo_lower_bound overshoots {len(violations)} done "
                f"row(s) — pruning certificate broken: {lines}")
        return table

    # ------------------------------------------------------------------
    def _execute(self, segs: Sequence[Segment],
                 per_seg_combos: Dict[str, List[Combination]],
                 knob_points: Sequence[GlobalKnobs],
                 rep: SweepReport, *,
                 mesh_points: Optional[Sequence[MeshSpec]],
                 kernel_tuning=None,
                 static_checks: str = "off",
                 backend: str, workers: int,
                 remote_url: Optional[str],
                 remote_token: Optional[str], fallback: Optional[str],
                 retry, transient_retries: Optional[int], prune: bool,
                 prune_margin: float, use_cache: bool,
                 share_scores: bool, record_batch: int,
                 boundary_slack: bool = False):
        """Score everything not already settled (Continue mode):
        Scheduler -> ScoringBackend -> Recorder, with bounded
        Scheduler-level transient retry rounds (``scheduler.drive``)."""
        from repro.core.backends import (RetryPolicy, drive, env_key,
                                         shape_key)
        # ONE key pair for the whole pipeline: the Recorder writes cache
        # entries and the workers read them under the same sk/mk.  A
        # swept mesh point overrides mk per job (JobSpec.mesh_key).
        sk, mk = shape_key(self.shape), env_key(self.mesh, self.executor)
        scheduler = Scheduler(
            self.db, self.project, self.cfg, self.shape, self.mesh,
            self.executor, validate=self.validate,
            share_scores=share_scores, use_cache=use_cache,
            shape_key=sk, mesh_key=mk, boundary_slack=boundary_slack,
            kernel_tuning=kernel_tuning, static_checks=static_checks,
            # the mesh-devices rule asks THIS host: valid for every
            # backend that scores locally, never for a remote server
            static_devices=(backend != "remote"))
        recorder = Recorder(
            self.db, self.project, rep, shape_key=sk, mesh_key=mk,
            use_cache=use_cache, batch=record_batch)
        work = scheduler.build(segs, per_seg_combos, recorder,
                               knob_points=knob_points,
                               mesh_points=mesh_points)

        engine, transient_engine = self._engine(
            backend, workers=workers, remote_url=remote_url,
            remote_token=remote_token, fallback=fallback, retry=retry,
            prune=prune, prune_margin=prune_margin, use_cache=use_cache,
            shape_key=sk, mesh_key=mk)
        policy = retry if retry is not None else RetryPolicy()
        rounds = policy.sweep_retries if transient_retries is None \
            else transient_retries
        try:
            drive(engine, work, recorder, transient_retries=rounds)
        finally:
            # flush BEFORE closing: results already scored must land in
            # the DB even if the engine's teardown throws — and a failing
            # close must never eat the recorder flush (or vice versa)
            try:
                recorder.flush()
            finally:
                if transient_engine:
                    engine.close()

    # ------------------------------------------------------------------
    def _engine(self, backend: str, *, workers: int,
                remote_url: Optional[str], remote_token: Optional[str],
                fallback: Optional[str], retry, prune: bool,
                prune_margin: float, use_cache: bool,
                shape_key: str, mesh_key: str):
        """Build a ScoringBackend; cache process backends for warm-worker
        reuse.

        A process pool pays ~seconds of jax import per spawned worker, so
        it is kept alive across ``sweep()`` calls on one tuner (same
        engine parameters) and only torn down by :meth:`close`.  Thread/
        sequential/remote backends hold no local resources (the remote
        backend's warm pool lives server-side) and are built per sweep.
        Returns ``(engine, transient)``; transient engines are closed by
        the caller after the run.  A cached engine that survived an
        aborted sweep culls its dead workers on reuse (see
        ``ProcessBackend.run``)."""
        kw = dict(
            workers=workers, prune=prune, prune_margin=prune_margin,
            timeout_s=getattr(self.executor, "timeout_s", None),
            # workers get a read-only cache view only when the cache is
            # on — use_cache=False must force real recompiles everywhere
            db_path=self.db.path if use_cache else None,
            shape_key=shape_key, mesh_key=mesh_key, remote_url=remote_url,
            token=remote_token, retry=retry, fallback=fallback)
        if backend != "process":
            return make_backend(backend, self.executor, self.cfg,
                                self.shape, **kw), True
        key = (backend,) + tuple(sorted(kw.items()))
        engine = self._engines.get(key)
        if engine is None:
            engine = make_backend(backend, self.executor, self.cfg,
                                  self.shape, **kw)
            self._engines[key] = engine
        return engine, False

    def close(self):
        """Release cached scoring backends (warm process-worker pools).
        Idempotent and exception-safe: one backend's failing teardown
        never leaks the others' worker pools.  Also runs on GC and via
        the context-manager exit."""
        engines, self._engines = self._engines, {}
        first_err = None
        for engine in engines.values():
            try:
                engine.close()
            except Exception as e:           # keep releasing the rest
                log.warning("engine close failed: %s", e)
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err

    def __enter__(self) -> "ComParTuner":
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # ------------------------------------------------------------------
    def baselines(self, knobs: GlobalKnobs = GlobalKnobs(), *,
                  global_space: Optional[Dict[str, Tuple]] = None):
        """Per-provider best uniform plans + the fused plan comparison
        (the numbers behind the Fig. 2/4 analogues).

        With ``global_space`` the baseline is per provider the best
        uniform plan over *any* swept knob point — the fair comparison
        against a joint-argmin fused plan.  Rows recorded by the pre-knob
        engine (no knob spec) count as the default point.  Rows from a
        swept ``mesh_space`` are grouped per mesh point (a uniform plan
        must live on ONE topology — mixing points across segments is not
        a realizable plan), and the baseline is the best over any
        point."""
        points = global_grid(global_space) if global_space is not None \
            else [knobs]
        kids = {kn.kid: kn for kn in points}
        segs = fragment(self.cfg)
        #: (mesh mid or "", knob kid) -> segment -> rows
        by_gid: Dict[Tuple[str, str],
                     Dict[str, List[Tuple[Combination, CostTerms]]]] = {}
        for r in self.db.results(self.project):
            if r["status"] != "done" or not r["cost"]:
                continue
            gid = (r["mesh"].mid if r["mesh"] is not None else "",
                   (r["knobs"] or GlobalKnobs()).kid)
            by_gid.setdefault(gid, {}).setdefault(r["segment"], []).append(
                (r["combo"], CostTerms.from_dict(r["cost"])))
        out = {}
        for pname in all_providers():
            best = None
            for (_, kid), rows in by_gid.items():
                kn = kids.get(kid)
                if kn is None:
                    continue
                per_seg = {s.name: [(c, t) for c, t in rows.get(s.name, [])
                                    if c.provider == pname] for s in segs}
                if not all(per_seg.values()):
                    continue
                try:
                    _, total = best_uniform(self.cfg, per_seg, kn)
                except ValueError:
                    continue
                if best is None or total < best:
                    best = total
            if best is not None:
                out[pname] = best
        return out
