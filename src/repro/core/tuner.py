"""ComParX tuner: the paper's end-to-end workflow (Fig. 1).

Fragmentor -> Combinator (-> DB register) -> Parallelizer+Executor per
combination (-> DB record, Continue-mode resumable) -> black-box validation
-> Optimal Plan Generator -> fused Plan.

The sweep execution core is the three-stage pipeline of
``repro.core.backends`` (see docs/sweep_engine.md):

* **Scheduler** — groups (segment, combination) rows that resolve to the
  *same program* (structural score sharing), resolves whole groups from
  the persistent cross-project ``score_cache``, and orders the remaining
  unique programs cheapest-lower-bound-first.
* **ScoringBackend** — scores unique programs: ``thread`` (PR-1
  semantics; soft off-main-thread deadline), ``sequential`` (one worker,
  no pool), or ``process`` (spawned workers; true parallel tracing past
  the GIL and a *hard* kill-based timeout with requeue-once-then-fail).
* **Recorder** — fans outcomes back out to member rows, keeps the
  report accounting, applies the cache policy (transient outcomes are
  never cached), and writes batched transactions.

Exact lower-bound pruning (never changes the argmin) runs inside the
backend against shared incumbents.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.backends import Recorder, Scheduler, make_backend
from repro.core.combinator import (Combination, GlobalKnobs,
                                   enumerate_combinations,
                                   paper_combination_count)
from repro.core.cost_model import CostTerms
from repro.core.db import SweepDB
from repro.core.executor import (DryRunExecutor, ParallelSweepRunner,  # noqa: F401  (ParallelSweepRunner re-exported for spies/back-compat)
                                 SweepJob, WallClockExecutor)
from repro.core.fusion import best_uniform, fuse
from repro.core.plan import Plan
from repro.core.providers import all_providers, get_provider
from repro.core.segment import Segment, fragment

log = logging.getLogger("repro.tuner")


@dataclass
class SweepReport:
    project: str
    n_combinations: int
    n_done: int = 0
    n_failed: int = 0
    n_invalid: int = 0
    n_pruned: int = 0       # rows skipped by the exact lower-bound prune
    n_scored: int = 0       # programs that actually compiled+analyzed
    n_cached: int = 0       # rows served from the persistent score cache
    n_shared: int = 0       # rows that shared an in-run compiled score
    n_transient: int = 0    # rows failed by deadline/crash (retryable)
    paper_count: int = 0
    elapsed_s: float = 0.0
    per_segment: Dict[str, List[Tuple[Combination, CostTerms]]] = \
        field(default_factory=dict)

    def summary(self) -> str:
        return (f"project={self.project} combos={self.n_combinations} "
                f"done={self.n_done} failed={self.n_failed} "
                f"invalid={self.n_invalid} pruned={self.n_pruned} "
                f"scored={self.n_scored} cached={self.n_cached} "
                f"shared={self.n_shared} transient={self.n_transient} "
                f"paper_formula_upper_bound={self.paper_count} "
                f"elapsed={self.elapsed_s:.1f}s")


class ComParTuner:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, mesh=None, *,
                 db: Optional[SweepDB] = None, project: Optional[str] = None,
                 mode: str = "new", executor: str = "dryrun",
                 validate: bool = False, timeout_s: Optional[int] = 300):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.db = db or SweepDB(":memory:")
        name = project or f"{cfg.name}-{shape.name}"
        self.project = self.db.open_project(
            name, mode, {"arch": cfg.name, "shape": shape.name})
        if executor == "dryrun":
            self.executor = DryRunExecutor(mesh, timeout_s=timeout_s)
        elif executor == "wallclock":
            self.executor = WallClockExecutor(mesh, timeout_s=timeout_s)
        else:
            raise ValueError(executor)
        self.validate = validate

    # ------------------------------------------------------------------
    def sweep(self, providers: Optional[Sequence[str]] = None,
              clause_space=None, *, budget: Optional[int] = None,
              knobs: GlobalKnobs = GlobalKnobs(),
              boundary_costs: bool = False,
              max_flags: Optional[int] = None,
              backend: str = "thread",
              workers: int = 1,
              prune: bool = False, prune_margin: float = 0.1,
              use_cache: bool = True, share_scores: bool = True,
              record_batch: int = 64) -> Tuple[Plan, SweepReport]:
        """Run the sweep.  Engine knobs (see docs/sweep_engine.md):

        ``backend``       scoring backend: ``thread`` (default) |
                          ``sequential`` | ``process``
        ``workers``       workers scoring unique programs (threads or
                          spawned processes, per ``backend``)
        ``prune``         exact lower-bound pruning on/off
        ``prune_margin``  relative headroom the bound must clear
        ``use_cache``     persistent structural score cache on/off
        ``share_scores``  group structurally identical rows into one
                          compile (off = one compile per row, the
                          pre-engine behavior — benchmark baseline)
        ``record_batch``  DB rows per write transaction
        """
        t0 = time.time()
        if prune and boundary_costs:
            # the lower-bound certificate covers the per-segment argmin
            # only; under Viterbi fusion a locally-dominated combination
            # can still win via cheaper boundary transitions
            log.warning("prune disabled: exactness doesn't extend to "
                        "boundary-cost (Viterbi) fusion")
            prune = False
        if backend == "process" and self.mesh is not None:
            # the wire format reconstructs arch/shape in the worker;
            # meshes (device handles) don't serialize
            log.warning("process backend needs a serializable job spec; "
                        "meshed sweeps fall back to the thread backend")
            backend = "thread"
        if workers > 1 and not getattr(self.executor, "parallel_safe", True):
            log.warning("workers=%d -> 1: %s timings would contend on the "
                        "device", workers, type(self.executor).__name__)
            workers = 1
        if prune and not hasattr(self.executor, "hw"):
            # the bound divides by the analytic hw model's peak; against an
            # executor measuring real wall seconds on unknown hardware the
            # certificate (bound <= score) no longer holds
            log.warning("prune disabled: %s has no hardware model",
                        type(self.executor).__name__)
            prune = False
        providers = list(providers or all_providers())
        segs = fragment(self.cfg)
        combos = enumerate_combinations(providers, clause_space,
                                        budget=budget, max_flags=max_flags)
        rep = SweepReport(
            self.project, n_combinations=0,
            paper_count=paper_combination_count(
                [len(get_provider(p).flags) for p in providers],
                n_rtl=len(vars(knobs)),
                n_d=len(clause_space or {}) or 6))

        # Combinator: register every (segment, combination), one transaction
        per_seg_combos: Dict[str, List[Combination]] = {}
        reg: List[Tuple[str, Combination]] = []
        for seg in segs:
            cs = [c for c in combos
                  if get_provider(c.provider).applicable(self.cfg, seg)]
            per_seg_combos[seg.name] = cs
            rep.n_combinations += len(cs)
            reg.extend((seg.name, c) for c in cs)
        self.db.register_many(self.project, reg)

        self._execute(segs, per_seg_combos, rep,
                      backend=backend, workers=workers, prune=prune,
                      prune_margin=prune_margin, use_cache=use_cache,
                      share_scores=share_scores, record_batch=record_batch)

        # collect valid results
        for seg in segs:
            rows = self.db.results(self.project, seg.name)
            good = [(r["combo"], CostTerms.from_dict(r["cost"]))
                    for r in rows if r["status"] == "done"]
            rep.per_segment[seg.name] = good
        counts = self.db.done_count(self.project)
        rep.n_done = counts.get("done", 0)
        rep.n_failed = counts.get("failed", 0)
        rep.n_invalid = counts.get("invalid", 0)
        rep.n_pruned = counts.get("pruned", 0)

        plan = fuse(self.cfg, self.shape, self.mesh, rep.per_segment,
                    knobs, boundary_costs=boundary_costs)
        plan.meta["project"] = self.project
        rep.elapsed_s = time.time() - t0
        log.info(rep.summary())
        return plan, rep

    # ------------------------------------------------------------------
    def _execute(self, segs: Sequence[Segment],
                 per_seg_combos: Dict[str, List[Combination]],
                 rep: SweepReport, *, backend: str, workers: int,
                 prune: bool, prune_margin: float, use_cache: bool,
                 share_scores: bool, record_batch: int):
        """Score everything not already settled (Continue mode):
        Scheduler -> ScoringBackend -> Recorder."""
        from repro.core.backends import env_key, shape_key
        # ONE key pair for the whole pipeline: the Recorder writes cache
        # entries and the workers read them under the same sk/mk
        sk, mk = shape_key(self.shape), env_key(self.mesh, self.executor)
        scheduler = Scheduler(
            self.db, self.project, self.cfg, self.shape, self.mesh,
            self.executor, validate=self.validate,
            share_scores=share_scores, use_cache=use_cache,
            shape_key=sk, mesh_key=mk)
        recorder = Recorder(
            self.db, self.project, rep, shape_key=sk, mesh_key=mk,
            use_cache=use_cache, batch=record_batch)
        work = scheduler.build(segs, per_seg_combos, recorder)

        engine = make_backend(
            backend, self.executor, self.cfg, self.shape,
            workers=workers, prune=prune, prune_margin=prune_margin,
            timeout_s=getattr(self.executor, "timeout_s", None),
            # workers get a read-only cache view only when the cache is
            # on — use_cache=False must force real recompiles everywhere
            db_path=self.db.path if use_cache else None,
            shape_key=sk, mesh_key=mk)
        try:
            for out in engine.run(work.jobs, incumbents=work.incumbents):
                recorder.outcome(work.groups[out.key], out)
        finally:
            engine.close()
            recorder.flush()

    # ------------------------------------------------------------------
    def baselines(self, knobs: GlobalKnobs = GlobalKnobs()):
        """Per-provider best uniform plans + the fused plan comparison
        (the numbers behind the Fig. 2/4 analogues)."""
        segs = fragment(self.cfg)
        rows = {s.name: [(r["combo"], CostTerms.from_dict(r["cost"]))
                         for r in self.db.results(self.project, s.name)
                         if r["status"] == "done"]
                for s in segs}
        out = {}
        for pname in all_providers():
            per_seg = {sn: [(c, t) for c, t in rs if c.provider == pname]
                       for sn, rs in rows.items()}
            if all(per_seg.values()):
                try:
                    plan, total = best_uniform(self.cfg, per_seg, knobs)
                    out[pname] = total
                except ValueError:
                    pass
        return out
