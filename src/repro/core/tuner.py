"""ComParX tuner: the paper's end-to-end workflow (Fig. 1).

Fragmentor -> Combinator (-> DB register) -> Parallelizer+Executor per
combination (-> DB record, Continue-mode resumable) -> black-box validation
-> Optimal Plan Generator -> fused Plan.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import (Combination, GlobalKnobs,
                                   enumerate_combinations,
                                   paper_combination_count)
from repro.core.cost_model import CostTerms
from repro.core.db import SweepDB
from repro.core.executor import (CombinationFailed, DryRunExecutor,
                                 WallClockExecutor)
from repro.core.fusion import best_uniform, fuse
from repro.core.plan import Plan
from repro.core.providers import all_providers, get_provider
from repro.core.segment import Segment, fragment
from repro.core.validator import validate_combination

log = logging.getLogger("repro.tuner")


@dataclass
class SweepReport:
    project: str
    n_combinations: int
    n_done: int = 0
    n_failed: int = 0
    n_invalid: int = 0
    paper_count: int = 0
    elapsed_s: float = 0.0
    per_segment: Dict[str, List[Tuple[Combination, CostTerms]]] = \
        field(default_factory=dict)

    def summary(self) -> str:
        return (f"project={self.project} combos={self.n_combinations} "
                f"done={self.n_done} failed={self.n_failed} "
                f"invalid={self.n_invalid} "
                f"paper_formula_upper_bound={self.paper_count} "
                f"elapsed={self.elapsed_s:.1f}s")


class ComParTuner:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, mesh=None, *,
                 db: Optional[SweepDB] = None, project: Optional[str] = None,
                 mode: str = "new", executor: str = "dryrun",
                 validate: bool = False, timeout_s: Optional[int] = 300):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.db = db or SweepDB(":memory:")
        name = project or f"{cfg.name}-{shape.name}"
        self.project = self.db.open_project(
            name, mode, {"arch": cfg.name, "shape": shape.name})
        if executor == "dryrun":
            self.executor = DryRunExecutor(mesh, timeout_s=timeout_s)
        elif executor == "wallclock":
            self.executor = WallClockExecutor(mesh, timeout_s=timeout_s)
        else:
            raise ValueError(executor)
        self.validate = validate

    # ------------------------------------------------------------------
    def sweep(self, providers: Optional[Sequence[str]] = None,
              clause_space=None, *, budget: Optional[int] = None,
              knobs: GlobalKnobs = GlobalKnobs(),
              boundary_costs: bool = False,
              max_flags: Optional[int] = None) -> Tuple[Plan, SweepReport]:
        t0 = time.time()
        providers = list(providers or all_providers())
        segs = fragment(self.cfg)
        combos = enumerate_combinations(providers, clause_space,
                                        budget=budget, max_flags=max_flags)
        rep = SweepReport(
            self.project, n_combinations=0,
            paper_count=paper_combination_count(
                [len(get_provider(p).flags) for p in providers],
                n_rtl=len(vars(knobs)),
                n_d=len(clause_space or {}) or 6))

        # Combinator: register every (segment, combination) in the DB
        per_seg_combos: Dict[str, List[Combination]] = {}
        for seg in segs:
            cs = [c for c in combos
                  if get_provider(c.provider).applicable(self.cfg, seg)]
            per_seg_combos[seg.name] = cs
            rep.n_combinations += len(cs)
            for c in cs:
                self.db.register(self.project, seg.name, c)

        # Executor: score everything not already done (Continue mode)
        for seg in segs:
            for c in per_seg_combos[seg.name]:
                st = self.db.status(self.project, seg.name, c.cid)
                if st in ("done", "failed", "invalid"):
                    continue
                self._run_one(seg, c, rep)

        # collect valid results
        for seg in segs:
            rows = self.db.results(self.project, seg.name)
            good = [(r["combo"], CostTerms.from_dict(r["cost"]))
                    for r in rows if r["status"] == "done"]
            rep.per_segment[seg.name] = good
        counts = self.db.done_count(self.project)
        rep.n_done = counts.get("done", 0)
        rep.n_failed = counts.get("failed", 0)
        rep.n_invalid = counts.get("invalid", 0)

        plan = fuse(self.cfg, self.shape, self.mesh, rep.per_segment,
                    knobs, boundary_costs=boundary_costs)
        plan.meta["project"] = self.project
        rep.elapsed_s = time.time() - t0
        log.info(rep.summary())
        return plan, rep

    def _run_one(self, seg: Segment, c: Combination, rep: SweepReport):
        if self.validate:
            ok, msg = validate_combination(self.cfg, c)
            if not ok:
                self.db.record(self.project, seg.name, c.cid,
                               status="invalid", error=msg)
                return
        try:
            cost = self.executor.score_segment(self.cfg, self.shape, seg, c)
        except CombinationFailed as e:
            self.db.record(self.project, seg.name, c.cid,
                           status="failed", error=str(e))
            return
        self.db.record(self.project, seg.name, c.cid, status="done",
                       cost=cost.as_dict())

    # ------------------------------------------------------------------
    def baselines(self, knobs: GlobalKnobs = GlobalKnobs()):
        """Per-provider best uniform plans + the fused plan comparison
        (the numbers behind the Fig. 2/4 analogues)."""
        segs = fragment(self.cfg)
        rows = {s.name: [(r["combo"], CostTerms.from_dict(r["cost"]))
                         for r in self.db.results(self.project, s.name)
                         if r["status"] == "done"]
                for s in segs}
        out = {}
        for pname in all_providers():
            per_seg = {sn: [(c, t) for c, t in rs if c.provider == pname]
                       for sn, rs in rows.items()}
            if all(per_seg.values()):
                try:
                    plan, total = best_uniform(self.cfg, per_seg, knobs)
                    out[pname] = total
                except ValueError:
                    pass
        return out
