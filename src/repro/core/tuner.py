"""ComParX tuner: the paper's end-to-end workflow (Fig. 1).

Fragmentor -> Combinator (-> DB register) -> Parallelizer+Executor per
combination (-> DB record, Continue-mode resumable) -> black-box validation
-> Optimal Plan Generator -> fused Plan.

The sweep execution core is a parallel, cache-aware, pruning engine:

* (segment, combination) rows that resolve to the *same program* — same
  segment signature, same segment-relevant clause fields, same resolved
  sharding mapping — are grouped and compiled once (structural score
  sharing; with no mesh, all providers collapse per clause).
* scored groups persist in a cross-project ``score_cache`` keyed by
  ``(segment_signature, shape, mesh, effective_cid)``, so a repeated sweep
  of the same config recompiles nothing.
* an analytic roofline lower bound prunes combinations that provably
  cannot beat a segment's incumbent best (exact — never changes the
  argmin); pruned rows are recorded with status ``pruned``.
* results are written in batched transactions (``record_many``) instead of
  one commit per row.
"""
from __future__ import annotations

import hashlib
import json
import logging
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import (Combination, GlobalKnobs, effective_cid,
                                   enumerate_combinations, mapping_key,
                                   paper_combination_count)
from repro.core.cost_model import CostTerms
from repro.core.db import SweepDB
from repro.core.executor import (DryRunExecutor, ParallelSweepRunner,
                                 SweepJob, WallClockExecutor)
from repro.core.fusion import best_uniform, fuse
from repro.core.plan import Plan
from repro.core.providers import all_providers, get_provider
from repro.core.segment import Segment, fragment
from repro.core.validator import validate_combination

log = logging.getLogger("repro.tuner")

#: statuses that Continue mode treats as settled (no re-run on resume)
_SETTLED = ("done", "failed", "invalid", "pruned")


def _shape_key(shape: ShapeConfig) -> str:
    return f"{shape.kind}:{shape.seq_len}x{shape.global_batch}"


def _mesh_key(mesh) -> str:
    if mesh is None:
        return "local"
    dev = mesh.devices.flat[0]
    blob = json.dumps({"axes": list(mesh.axis_names),
                       "shape": [int(d) for d in mesh.devices.shape],
                       "platform": str(getattr(dev, "platform", "?"))})
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


@dataclass
class SweepReport:
    project: str
    n_combinations: int
    n_done: int = 0
    n_failed: int = 0
    n_invalid: int = 0
    n_pruned: int = 0       # rows skipped by the exact lower-bound prune
    n_scored: int = 0       # programs actually compiled+analyzed this run
    n_cached: int = 0       # rows served from the persistent score cache
    n_shared: int = 0       # rows that shared an in-run score (beyond rep.)
    paper_count: int = 0
    elapsed_s: float = 0.0
    per_segment: Dict[str, List[Tuple[Combination, CostTerms]]] = \
        field(default_factory=dict)

    def summary(self) -> str:
        return (f"project={self.project} combos={self.n_combinations} "
                f"done={self.n_done} failed={self.n_failed} "
                f"invalid={self.n_invalid} pruned={self.n_pruned} "
                f"scored={self.n_scored} cached={self.n_cached} "
                f"shared={self.n_shared} "
                f"paper_formula_upper_bound={self.paper_count} "
                f"elapsed={self.elapsed_s:.1f}s")


@dataclass
class _Group:
    """All pending (segment, cid) rows that share one program."""
    seg: Segment
    combo: Combination
    signature: str
    eff_cid: str
    members: List[Tuple[str, str]] = field(default_factory=list)

    @property
    def segment_names(self) -> Tuple[str, ...]:
        return tuple(sorted({s for s, _ in self.members}))


class ComParTuner:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, mesh=None, *,
                 db: Optional[SweepDB] = None, project: Optional[str] = None,
                 mode: str = "new", executor: str = "dryrun",
                 validate: bool = False, timeout_s: Optional[int] = 300):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.db = db or SweepDB(":memory:")
        name = project or f"{cfg.name}-{shape.name}"
        self.project = self.db.open_project(
            name, mode, {"arch": cfg.name, "shape": shape.name})
        if executor == "dryrun":
            self.executor = DryRunExecutor(mesh, timeout_s=timeout_s)
        elif executor == "wallclock":
            self.executor = WallClockExecutor(mesh, timeout_s=timeout_s)
        else:
            raise ValueError(executor)
        self.validate = validate

    # ------------------------------------------------------------------
    def sweep(self, providers: Optional[Sequence[str]] = None,
              clause_space=None, *, budget: Optional[int] = None,
              knobs: GlobalKnobs = GlobalKnobs(),
              boundary_costs: bool = False,
              max_flags: Optional[int] = None,
              workers: int = 1,
              prune: bool = False, prune_margin: float = 0.1,
              use_cache: bool = True, share_scores: bool = True,
              record_batch: int = 64) -> Tuple[Plan, SweepReport]:
        """Run the sweep.  Engine knobs (see docs/sweep_engine.md):

        ``workers``       worker threads scoring unique programs
        ``prune``         exact lower-bound pruning on/off
        ``prune_margin``  relative headroom the bound must clear
        ``use_cache``     persistent structural score cache on/off
        ``share_scores``  group structurally identical rows into one
                          compile (off = one compile per row, the
                          pre-engine behavior — benchmark baseline)
        ``record_batch``  DB rows per write transaction
        """
        t0 = time.time()
        if prune and boundary_costs:
            # the lower-bound certificate covers the per-segment argmin
            # only; under Viterbi fusion a locally-dominated combination
            # can still win via cheaper boundary transitions
            log.warning("prune disabled: exactness doesn't extend to "
                        "boundary-cost (Viterbi) fusion")
            prune = False
        if workers > 1 and not getattr(self.executor, "parallel_safe", True):
            log.warning("workers=%d -> 1: %s timings would contend on the "
                        "device", workers, type(self.executor).__name__)
            workers = 1
        if prune and not hasattr(self.executor, "hw"):
            # the bound divides by the analytic hw model's peak; against an
            # executor measuring real wall seconds on unknown hardware the
            # certificate (bound <= score) no longer holds
            log.warning("prune disabled: %s has no hardware model",
                        type(self.executor).__name__)
            prune = False
        providers = list(providers or all_providers())
        segs = fragment(self.cfg)
        combos = enumerate_combinations(providers, clause_space,
                                        budget=budget, max_flags=max_flags)
        rep = SweepReport(
            self.project, n_combinations=0,
            paper_count=paper_combination_count(
                [len(get_provider(p).flags) for p in providers],
                n_rtl=len(vars(knobs)),
                n_d=len(clause_space or {}) or 6))

        # Combinator: register every (segment, combination), one transaction
        per_seg_combos: Dict[str, List[Combination]] = {}
        reg: List[Tuple[str, Combination]] = []
        for seg in segs:
            cs = [c for c in combos
                  if get_provider(c.provider).applicable(self.cfg, seg)]
            per_seg_combos[seg.name] = cs
            rep.n_combinations += len(cs)
            reg.extend((seg.name, c) for c in cs)
        self.db.register_many(self.project, reg)

        self._execute(segs, per_seg_combos, rep,
                      workers=workers, prune=prune,
                      prune_margin=prune_margin, use_cache=use_cache,
                      share_scores=share_scores, record_batch=record_batch)

        # collect valid results
        for seg in segs:
            rows = self.db.results(self.project, seg.name)
            good = [(r["combo"], CostTerms.from_dict(r["cost"]))
                    for r in rows if r["status"] == "done"]
            rep.per_segment[seg.name] = good
        counts = self.db.done_count(self.project)
        rep.n_done = counts.get("done", 0)
        rep.n_failed = counts.get("failed", 0)
        rep.n_invalid = counts.get("invalid", 0)
        rep.n_pruned = counts.get("pruned", 0)

        plan = fuse(self.cfg, self.shape, self.mesh, rep.per_segment,
                    knobs, boundary_costs=boundary_costs)
        plan.meta["project"] = self.project
        rep.elapsed_s = time.time() - t0
        log.info(rep.summary())
        return plan, rep

    # ------------------------------------------------------------------
    def _execute(self, segs: Sequence[Segment],
                 per_seg_combos: Dict[str, List[Combination]],
                 rep: SweepReport, *, workers: int, prune: bool,
                 prune_margin: float, use_cache: bool, share_scores: bool,
                 record_batch: int):
        """Score everything not already settled (Continue mode)."""
        statuses = self.db.statuses(self.project)
        shape_key = _shape_key(self.shape)
        # the mesh column doubles as the execution-environment key: scores
        # from a different executor or hardware model are not interchangeable
        mesh_key = (f"{_mesh_key(self.mesh)}/"
                    f"{getattr(self.executor, 'cache_tag', 'unknown')}")

        # incumbent best per segment, seeded from prior rows (resume)
        incumbents: Dict[str, float] = {}
        for r in self.db.results(self.project):
            if r["status"] == "done" and r["cost"]:
                t = CostTerms.from_dict(r["cost"]).total_s
                cur = incumbents.get(r["segment"])
                if cur is None or t < cur:
                    incumbents[r["segment"]] = t

        # group pending rows by structural program identity
        groups: Dict[str, _Group] = {}
        pending_records: List[Dict] = []
        valid_memo: Dict[str, Tuple[bool, str]] = {}
        for seg in segs:
            sig = seg.signature(self.cfg, self.shape)
            relevant = seg.relevant_clause_fields(self.shape.kind)
            for c in per_seg_combos[seg.name]:
                if statuses.get((seg.name, c.cid)) in _SETTLED:
                    continue
                if self.validate:
                    if c.cid not in valid_memo:
                        valid_memo[c.cid] = validate_combination(self.cfg, c)
                    ok, msg = valid_memo[c.cid]
                    if not ok:
                        pending_records.append(
                            {"segment": seg.name, "cid": c.cid,
                             "status": "invalid", "error": msg})
                        continue
                ec = effective_cid(
                    c, relevant, mapping_key(self.cfg, self.mesh, c, seg))
                key = f"{sig}/{ec}" if share_scores \
                    else f"{seg.name}/{c.cid}"
                g = groups.setdefault(key, _Group(seg, c, sig, ec))
                g.members.append((seg.name, c.cid))

        # persistent cache stage: resolve whole groups without compiling
        jobs: List[SweepJob] = []
        for key, g in groups.items():
            hit = self.db.cache_get(g.signature, shape_key, mesh_key,
                                    g.eff_cid) if use_cache else None
            if hit is not None:
                rep.n_cached += len(g.members)
                for sname, cid in g.members:
                    pending_records.append(
                        {"segment": sname, "cid": cid,
                         "status": hit["status"], "cost": hit["cost"],
                         "error": hit["error"]})
                if hit["status"] == "done" and hit["cost"]:
                    t = CostTerms.from_dict(hit["cost"]).total_s
                    for sname in g.segment_names:
                        if t < incumbents.get(sname, float("inf")):
                            incumbents[sname] = t
                continue
            jobs.append(SweepJob(key, g.seg, g.combo,
                                 segments=g.segment_names))
        self.db.record_many(self.project, pending_records)
        pending_records = []

        # runner stage: compile+score unique programs, fan results out
        runner = ParallelSweepRunner(
            self.executor, self.cfg, self.shape, workers=workers,
            prune=prune, prune_margin=prune_margin)
        cache_entries: List[Dict] = []
        for res in runner.run(jobs, incumbents=incumbents):
            g = groups[res.job.key]
            cost_d = res.cost.as_dict() if res.cost is not None else None
            for sname, cid in g.members:
                pending_records.append(
                    {"segment": sname, "cid": cid, "status": res.status,
                     "cost": cost_d, "error": res.error})
            if res.status == "pruned":
                rep.n_pruned += len(g.members)
            else:
                rep.n_scored += 1
                rep.n_shared += len(g.members) - 1
                # pruned outcomes are project-relative (they depend on the
                # incumbent) and must NOT be cached; neither are deadline
                # failures, which depend on machine load / timeout_s — a
                # bigger budget must be able to retry them.  Lowering and
                # sharding failures ARE deterministic and cacheable.
                if use_cache and not (res.status == "failed"
                                      and "deadline" in res.error):
                    cache_entries.append(
                        {"signature": g.signature, "shape": shape_key,
                         "mesh": mesh_key, "cid": g.eff_cid,
                         "status": res.status, "cost": cost_d,
                         "error": res.error})
            if len(pending_records) >= record_batch:
                self.db.record_many(self.project, pending_records)
                pending_records = []
                if use_cache and cache_entries:
                    self.db.cache_put_many(cache_entries)
                    cache_entries = []
        self.db.record_many(self.project, pending_records)
        if use_cache and cache_entries:
            self.db.cache_put_many(cache_entries)

    # ------------------------------------------------------------------
    def baselines(self, knobs: GlobalKnobs = GlobalKnobs()):
        """Per-provider best uniform plans + the fused plan comparison
        (the numbers behind the Fig. 2/4 analogues)."""
        segs = fragment(self.cfg)
        rows = {s.name: [(r["combo"], CostTerms.from_dict(r["cost"]))
                         for r in self.db.results(self.project, s.name)
                         if r["status"] == "done"]
                for s in segs}
        out = {}
        for pname in all_providers():
            per_seg = {sn: [(c, t) for c, t in rs if c.provider == pname]
                       for sn, rs in rows.items()}
            if all(per_seg.values()):
                try:
                    plan, total = best_uniform(self.cfg, per_seg, knobs)
                    out[pname] = total
                except ValueError:
                    pass
        return out
