"""Black-box validation (paper §4.1).

ComPar optionally runs a user testing script on every combination's output
and rejects combinations that fail.  ComParX's analogue: run the candidate
plan's step on a reduced config with real numerics (CPU) and compare
logits/loss against the reference plan (single-device, XLA kernels, no
remat).  Sharding choices must be numerics-preserving; kernel/remat
clauses must stay within tolerance.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.combinator import Combination
from repro.core.plan import Plan, build_contexts, uniform_plan
from repro.models.context import SegmentClause
from repro.models.model import forward, model_specs
from repro.models.params import init_params


def _tiny_batch(cfg: ArchConfig, batch: int = 2, seq: int = 16, seed: int = 0):
    ks = jax.random.split(jax.random.key(seed), 3)
    out = {"targets": jax.random.randint(ks[0], (batch, seq), 0,
                                         cfg.vocab_size)}
    if cfg.frontend != "none":
        out["embeds"] = (jax.random.normal(
            ks[1], (batch, seq, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.dtype)
    else:
        out["tokens"] = jax.random.randint(ks[2], (batch, seq), 0,
                                           cfg.vocab_size)
    return out


def validate_plan(cfg: ArchConfig, plan: Plan, *,
                  reference: Optional[Plan] = None,
                  atol: float = 5e-2, rtol: float = 5e-2,
                  seed: int = 0) -> Tuple[bool, str]:
    """Black-box test: candidate-vs-reference forward on a reduced config.

    Returns (passed, message).  Runs on the reduced (smoke) config so it is
    executable on this CPU container regardless of the target scale.
    """
    small = cfg if cfg.name.endswith("-smoke") else cfg.smoke()
    reference = reference or uniform_plan(
        small, "fsdp", clause=SegmentClause(remat="none", kernel="xla"))
    params = init_params(model_specs(small), jax.random.key(seed))
    batch = _tiny_batch(small, seed=seed)

    def run(p):
        ctxs = build_contexts(small, None, p, interpret=True)
        logits, aux = forward(params, batch, small, ctxs)
        return np.asarray(logits, np.float32)

    try:
        cand = run(plan)
    except Exception as e:
        return False, f"candidate failed to execute: {type(e).__name__}: {e}"
    ref = run(reference)
    if np.any(np.isnan(cand)):
        return False, "candidate produced NaNs"
    err = float(np.max(np.abs(cand - ref)))
    scale = float(np.max(np.abs(ref)) + 1e-9)
    if err > atol + rtol * scale:
        return False, f"output mismatch: max_abs_err={err:.4g} scale={scale:.4g}"
    return True, f"ok (max_abs_err={err:.4g})"


def validate_combination(cfg: ArchConfig, combo: Combination,
                         **kw) -> Tuple[bool, str]:
    """Validate one combination applied uniformly (cheapest black-box)."""
    small = cfg if cfg.name.endswith("-smoke") else cfg.smoke()
    plan = uniform_plan(small, combo.provider, combo.flags, combo.clause)
    return validate_plan(small, plan, **kw)
