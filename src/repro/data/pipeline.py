"""Deterministic, resumable, host-sharded synthetic LM data pipeline.

Every batch is a pure function of (seed, step, host slice), so:

* restart at step k replays exactly the same stream (fault tolerance);
* each host materializes only its slice of the global batch (the same
  contract a real multi-host loader has on a 1000-node pod);
* no filesystem or network dependency in this container.

The token stream is a mixture of structured patterns (ramps, repeats,
n-gram motifs) rather than iid noise, so a ~100M model trained on it shows
a real, visible loss curve (examples/train_e2e.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class DataState:
    """Serializable pipeline position (goes into every checkpoint)."""
    seed: int
    step: int

    def as_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["seed"]), int(d["step"]))


def _batch_tokens(key, batch: int, seq: int, vocab: int) -> jax.Array:
    """Structured pseudo-language: motif repetition + local ramps."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    motif_len = 16
    n_motifs = 32
    motifs = jax.random.randint(k1, (n_motifs, motif_len), 0, vocab)
    idx = jax.random.randint(k2, (batch, (seq + motif_len - 1) // motif_len),
                             0, n_motifs)
    base = motifs[idx].reshape(batch, -1)[:, :seq]
    ramp = (jnp.arange(seq)[None, :]
            + jax.random.randint(k3, (batch, 1), 0, vocab)) % vocab
    use_ramp = jax.random.bernoulli(k4, 0.3, (batch, 1))
    return jnp.where(use_ramp, ramp, base).astype(jnp.int32)


class SyntheticLM:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, *,
                 seed: int = 0, host_index: int = 0, host_count: int = 1):
        assert shape.global_batch % host_count == 0 or host_count == 1
        self.cfg = cfg
        self.shape = shape
        self.state = DataState(seed, 0)
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = max(1, shape.global_batch // host_count)

    def _key(self, step: int):
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.state.seed), step),
            self.host_index)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        """Pure function of step (the resumability contract)."""
        key = self._key(step)
        B, S, V = self.local_batch, self.shape.seq_len, self.cfg.vocab_size
        toks = _batch_tokens(key, B, S + 1, V)
        out: Dict[str, jax.Array] = {"targets": toks[:, 1:]}
        if self.cfg.frontend != "none":
            ke = jax.random.fold_in(key, 7)
            out["embeds"] = (jax.random.normal(
                ke, (B, S, self.cfg.d_model), jnp.float32) * 0.02
            ).astype(self.cfg.dtype)
        else:
            out["tokens"] = toks[:, :-1]
        return out

    def __next__(self) -> Dict[str, jax.Array]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    def __iter__(self):
        return self

    # --- checkpoint integration ---------------------------------------
    def snapshot(self) -> Dict:
        return self.state.as_dict()

    def restore(self, d: Dict):
        self.state = DataState.from_dict(d)
