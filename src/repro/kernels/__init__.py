"""Public kernels API: model-layout ops + the schedule autotuner.

Callers import from here (``from repro import kernels as kops`` /
``from repro.kernels import flash_attention``) instead of reaching into
``repro.kernels.ops`` — the op wrappers and the autotune entry points
are one surface, so the kernel axis and the kernels themselves version
together.
"""
from repro.kernels.ops import (flash_attention, flash_decode,
                               mlstm_chunkwise, rglru, rmsnorm)
from repro.kernels.autotune import (DEFAULT_KERNEL_SPACE,
                                    KERNEL_CACHE_VERSION, KernelTuning,
                                    OP_FIELDS, cache_key, clause_schedule,
                                    measure_op, op_variants, schedule_key,
                                    segment_ops, tune_segments)

__all__ = [
    # ops (model-layout adapters, differentiable via custom_vjp)
    "flash_attention", "flash_decode", "mlstm_chunkwise", "rglru",
    "rmsnorm",
    # autotuner (the hierarchical kernel-schedule axis)
    "DEFAULT_KERNEL_SPACE", "KERNEL_CACHE_VERSION", "KernelTuning",
    "OP_FIELDS", "cache_key", "clause_schedule", "measure_op",
    "op_variants", "schedule_key", "segment_ops", "tune_segments",
]
