"""Hierarchical kernel-schedule autotuner (the inner sweep level).

A flat clause sweep pays a full segment-program compile per (kernel,
tile) point — a T-schedule grid multiplies the outer (provider x flags x
clause) cross-product by T.  This module tunes kernels *in isolation*
instead: it enumerates kernel schedules — ``kernel in {xla, pallas}``
crossed with the ``block_q``/``block_k``/``mlstm_chunk`` grids — per
(op, shape signature, dtype, platform), times each variant as a
standalone program (wallclock median-of-k on real devices; the
``MachineProfile``-backed dryrun estimate on the CPU container), and
persists the results in a versioned ``kernel_cache`` WAL table keyed
like ``machine_cache`` so repeat sweeps re-benchmark nothing.

The outer engine (``ComParTuner.sweep(kernel_space=..., kernel_top_k=N)``)
then carries only the **top-k surviving schedules per segment** into the
cross-product: a T-schedule grid adds at most k outer combos per
affected segment instead of xT compiles.  Exactness contract: the
kernel-aware compute floor fed into ``combo_lower_bound`` is the
trip-count-exact HLO flop count of the *variant the combination actually
uses* (and therefore >= the minimum over measured variants), measured
from the same lowering the outer program embeds — so ``prune=True``
stays exact and the fused plan still pins the true per-segment schedule.

Cache key format (mirrors ``machine.profile_key``)::

    kernel:v<KERNEL_CACHE_VERSION>:<executor cache_tag>:<op>:<dims>

with one row per (key, canonical variant key).  The executor tag
(``dryrun:<hw.name>`` / ``wallclock:r<k>:<platform>``) keeps calibrated,
constant-model and empirical timings in disjoint rows; the version bump
retires old measurement semantics without aliasing.
"""
from __future__ import annotations

import itertools
import logging
from typing import Dict, List, Optional, Sequence, Tuple

log = logging.getLogger("repro.autotune")

#: bump on any change to what the microbenchmarks measure or how rows
#: are keyed — stale-version rows are then unreachable (never trusted).
KERNEL_CACHE_VERSION = 1

#: the SegmentClause fields that select each op's schedule, in the order
#: they are keyed.  ``scan_unroll`` is deliberately absent: it shapes the
#: layer scan around the ops, never an op invocation, so it rides the
#: outer clause space unmeasured.
OP_FIELDS: Dict[str, Tuple[str, ...]] = {
    "flash_attention": ("kernel", "block_q", "block_k"),
    "flash_decode": ("kernel", "block_k"),
    "mlstm_chunkwise": ("kernel", "mlstm_chunk"),
    "rglru": ("kernel", "mlstm_chunk"),
}

#: default inner grid for ``kernel_space="auto"`` — the tile/variant
#: search the tuner runs when the caller doesn't supply one.
DEFAULT_KERNEL_SPACE: Dict[str, Tuple] = {
    "kernel": ("xla", "pallas"),
    "block_q": (256, 512),
    "block_k": (512, 1024),
    "mlstm_chunk": (128, 256),
}


def schedule_key(fields: Dict[str, object]) -> str:
    """Canonical id of one schedule point (sorted ``k=v`` join) — the
    ``kernel_cache`` variant column and the tuner-side projection key."""
    return ",".join(f"{k}={fields[k]}" for k in sorted(fields))


def clause_schedule(clause, fields: Sequence[str]) -> str:
    """Project a SegmentClause onto ``fields`` -> canonical schedule key.
    This is how the outer sweep asks "which measured variant does this
    combination use?" — shared by the combo filter and the bound."""
    return schedule_key({f: getattr(clause, f) for f in fields})


def segment_ops(cfg, shape, seg) -> Dict[str, int]:
    """op name -> invocation count in one forward pass of ``seg``.

    Mirrors the model dispatch sites exactly: attention blocks call
    ``flash_attention`` on full-sequence shapes and ``flash_decode`` on
    decode — except windowed decode, whose ring-buffer path never
    reaches the kernel dispatch (``attn_decode``).  mLSTM / RG-LRU
    blocks only dispatch on full-sequence shapes (their decode paths are
    single-step updates).  sLSTM has no kernel dispatch at all.
    """
    if seg.kind != "stack":
        return {}
    full_seq = shape.kind in ("train", "prefill")
    counts: Dict[str, int] = {}

    def add(op):
        counts[op] = counts.get(op, 0) + seg.repeats

    for k in seg.pattern:
        if k.startswith("attn"):
            if full_seq:
                add("flash_attention")
            elif shape.kind == "decode" and not cfg.window_size:
                add("flash_decode")
        elif k == "mlstm" and full_seq:
            add("mlstm_chunkwise")
        elif k == "rec" and full_seq:
            add("rglru")
    return counts


def _op_dims(op: str, cfg, shape) -> str:
    """Shape-signature component of the cache key: everything that
    determines the op's input shapes/dtype and masking."""
    B, S = shape.global_batch, shape.seq_len
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    if op == "flash_attention":
        return f"B{B}S{S}H{H}KV{KV}D{D}w{cfg.window_size}:{cfg.dtype}"
    if op == "flash_decode":
        return f"B{B}Smax{S}H{H}KV{KV}D{D}:{cfg.dtype}"
    if op == "mlstm_chunkwise":
        di = int(cfg.expand_factor * cfg.d_model)
        return f"B{B}S{S}H{H}dh{di // H}:float32"
    if op == "rglru":
        dr = int(cfg.expand_factor * cfg.d_model)
        return f"B{B}S{S}dr{dr}:float32"
    raise KeyError(op)


def cache_key(op: str, cfg, shape, tag: str) -> str:
    """Versioned ``kernel_cache`` primary key (see module docstring)."""
    return (f"kernel:v{KERNEL_CACHE_VERSION}:{tag}:{op}:"
            f"{_op_dims(op, cfg, shape)}")


# --- isolated op programs ----------------------------------------------------
#
# Each builder returns ``(fn, arg_specs)`` where fn mirrors the model
# call site byte-for-byte (same clamping, same layouts), so the measured
# lowering is the one the outer segment program embeds.

def _clamp_chunk(chunk: int, S: int) -> int:
    c = min(int(chunk), S)
    while S % c:
        c -= 1
    return c


def _op_program(op: str, fields: Dict[str, object], cfg, shape,
                interpret: bool = True):
    import jax
    import jax.numpy as jnp

    B, S = shape.global_batch, shape.seq_len
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    f32 = jnp.dtype("float32")
    kernel = fields.get("kernel", "xla")

    if op == "flash_attention":
        q = jax.ShapeDtypeStruct((B, S, H, D), dt)
        kv = jax.ShapeDtypeStruct((B, S, KV, D), dt)
        bq, bk = int(fields["block_q"]), int(fields["block_k"])
        if kernel == "pallas":
            from repro.kernels.ops import flash_attention

            def fn(q, k, v):
                return flash_attention(q, k, v, causal=True,
                                       window=cfg.window_size,
                                       block_q=bq, block_k=bk,
                                       interpret=interpret)
        else:
            from repro.models.attention import chunked_attention

            def fn(q, k, v):
                pos = jnp.arange(S)
                return chunked_attention(q, k, v, pos_q=pos, pos_k=pos,
                                         window=cfg.window_size, q_chunk=bq)
        return fn, (q, kv, kv)

    if op == "flash_decode":
        q = jax.ShapeDtypeStruct((B, H, D), dt)
        cache = jax.ShapeDtypeStruct((B, S, KV, D), dt)
        bk = int(fields["block_k"])
        pos = S // 2                       # mid-cache: the typical token
        if kernel == "pallas":
            from repro.kernels.ops import flash_decode

            def fn(q, k, v):
                return flash_decode(q, k, v, pos, block_k=bk,
                                    interpret=interpret)
        else:
            from repro.models.attention import decode_attention

            def fn(q, k, v):
                # measured with the cheaper bf16-read path: the floor
                # must stay under BOTH cache_upcast settings
                return decode_attention(q, k, v, pos, upcast=False)
        return fn, (q, cache, cache)

    if op == "mlstm_chunkwise":
        di = int(cfg.expand_factor * cfg.d_model)
        dh = di // H
        qkv = jax.ShapeDtypeStruct((B, H, S, dh), f32)
        g = jax.ShapeDtypeStruct((B, H, S), f32)
        c = _clamp_chunk(fields["mlstm_chunk"], S)
        if kernel == "pallas":
            from repro.kernels.ops import mlstm_chunkwise

            def fn(q, k, v, li, lf):
                return mlstm_chunkwise(q, k, v, li, lf, chunk=c,
                                       interpret=interpret)
        else:
            from repro.models.xlstm import mlstm_chunk

            def fn(q, k, v, li, lf):
                nc = S // c
                rs = lambda t: jnp.moveaxis(
                    t.reshape(*t.shape[:2], nc, c, *t.shape[3:]), 2, 0)
                state0 = (jnp.zeros((B, H, dh, dh), jnp.float32),
                          jnp.zeros((B, H, dh), jnp.float32),
                          jnp.zeros((B, H), jnp.float32))

                def step(state, inp):
                    h, new = mlstm_chunk(*inp, state)
                    return new, h
                _, hs = jax.lax.scan(step, state0,
                                     (rs(q), rs(k), rs(v), rs(li), rs(lf)))
                return jnp.moveaxis(hs, 0, 2).reshape(B, H, S, dh)
        return fn, (qkv, qkv, qkv, g, g)

    if op == "rglru":
        dr = int(cfg.expand_factor * cfg.d_model)
        ab = jax.ShapeDtypeStruct((B, S, dr), f32)
        if kernel == "pallas":
            from repro.kernels.ops import rglru
            c = _clamp_chunk(fields["mlstm_chunk"], S)

            def fn(log_a, b):
                return rglru(log_a, b, chunk=c, interpret=interpret)
        else:
            from repro.models.rglru import rglru_scan

            def fn(log_a, b):
                return rglru_scan(jnp.exp(log_a), b)
        return fn, (ab, ab)

    raise KeyError(op)


# --- measurement -------------------------------------------------------------

def op_variants(op: str, space: Dict[str, Tuple]) -> List[Dict[str, object]]:
    """The variant grid of one op under a (merged) clause space: the
    cross-product of its :data:`OP_FIELDS` values.  Fields absent from
    the space fall back to the SegmentClause default, so every projection
    of an outer-space combination is a measured variant."""
    from repro.models.context import SegmentClause
    default = SegmentClause()
    fields = OP_FIELDS[op]
    values = [tuple(space.get(f) or (getattr(default, f),)) for f in fields]
    return [dict(zip(fields, point))
            for point in itertools.product(*values)]


def _measure_one(op: str, fields: Dict[str, object], cfg, shape,
                 executor) -> Dict[str, object]:
    """Time one (op, schedule) variant in isolation.

    Dryrun (executor has an ``hw`` model): compile + trip-count-exact
    HLO analysis — ``time_s`` is the modeled roofline total, ``flops``
    the exact count feeding the kernel-aware pruning floor.  Wallclock:
    median-of-k measured seconds, ``flops=0`` (no floor — pruning is
    force-disabled for wallclock sweeps anyway).

    Transient failures (deadline) return ``status="transient"`` and are
    NEVER persisted; deterministic failures are cached as ``"failed"``
    so a broken variant is rejected for free on the next sweep.
    """
    from repro.core.executor import (CombinationFailed, analyze_compiled,
                                     deadline, lower_and_compile)
    try:
        # static pre-check: the op programs call the kernels directly, so
        # a tile-divisibility ERROR from the schedule lint is exactly the
        # assert the compile would die on — reject it without compiling.
        # Deterministic (rule-set) verdict, so caching it as "failed" is
        # as sound as caching the compile failure it predicts.
        from repro.analysis.rules import lint_schedule
        errs = [d for d in lint_schedule(op, fields, cfg, shape)
                if d.is_error]
        if errs:
            return {"status": "failed", "error": "static: " +
                    "; ".join(f"{d.rule}: {d.message}" for d in errs)}
        with deadline(getattr(executor, "timeout_s", None)):
            fn, args = _op_program(op, fields, cfg, shape)
            hw = getattr(executor, "hw", None)
            if hw is not None:
                lowered, compiled = lower_and_compile(fn, args, None, None)
                terms = analyze_compiled(lowered, compiled, 1, hw)
                return {"status": "done", "time_s": terms.total_s,
                        "flops": terms.flops}
            import time as _time

            import jax
            import numpy as np
            from repro.core.executor import _materialize
            concrete = [_materialize(a) for a in args]
            jitted = jax.jit(fn)
            jax.block_until_ready(jitted(*concrete))        # compile + warm
            repeats = max(1, int(getattr(executor, "repeats", 3)))
            times = []
            for _ in range(repeats):
                t0 = _time.perf_counter()
                jax.block_until_ready(jitted(*concrete))
                times.append(_time.perf_counter() - t0)
            return {"status": "done", "time_s": float(np.median(times)),
                    "flops": 0.0}
    except CombinationFailed as e:
        if getattr(e, "transient", False):
            return {"status": "transient", "error": str(e)}
        return {"status": "failed", "error": str(e)}
    except Exception as e:
        return {"status": "failed", "error": f"{type(e).__name__}: {e}"}


def measure_op(db, op: str, cfg, shape, space: Dict[str, Tuple],
               executor, use_cache: bool = True
               ) -> Tuple[Dict[str, Dict], int, int]:
    """Measure (or cache-resolve) every variant of one op.

    Returns ``(results, n_timed, n_cached)`` where results maps the
    canonical variant key -> {"status", "time_s", "flops", ...}.
    """
    tag = getattr(executor, "cache_tag", "unknown")
    key = cache_key(op, cfg, shape, tag)
    cached = db.kernel_get(key) if (db is not None and use_cache) else {}
    results: Dict[str, Dict] = {}
    fresh: Dict[str, Dict] = {}
    n_timed = 0
    for fields in op_variants(op, space):
        vkey = schedule_key(fields)
        if vkey in results:
            continue
        hit = cached.get(vkey)
        if hit is not None:
            results[vkey] = hit
            continue
        entry = _measure_one(op, fields, cfg, shape, executor)
        n_timed += 1
        results[vkey] = entry
        if entry["status"] != "transient":    # never persist load-dependent
            fresh[vkey] = entry
    if fresh and db is not None and use_cache:
        db.kernel_put_many(key, fresh)
    n_cached = len(results) - n_timed
    return results, n_timed, n_cached


# --- per-segment ranking -----------------------------------------------------

class KernelTuning:
    """The inner sweep's verdict, consumed by the outer engine.

    * ``fields``    segment name -> sorted tuple of tuned clause fields
    * ``surviving`` segment name -> set of top-k schedule keys (over the
      segment's ``fields`` projection); segments with no tuned ops are
      absent — they stay unrestricted.
    * ``floors``    segment name -> {schedule key -> certified isolated
      kernel flops} (dryrun only; wallclock measures no flops)
    * ``report``    the ``SweepReport.kernel_tuning`` observability dict
    """

    def __init__(self):
        self.fields: Dict[str, Tuple[str, ...]] = {}
        self.surviving: Dict[str, set] = {}
        self.floors: Dict[str, Dict[str, float]] = {}
        self.report: Dict[str, object] = {}

    def keeps(self, seg_name: str, clause) -> bool:
        """Does the outer sweep carry this combination for ``seg_name``?"""
        keep = self.surviving.get(seg_name)
        if keep is None:
            return True
        return clause_schedule(clause, self.fields[seg_name]) in keep

    def floor_flops(self, seg_name: str, clause) -> float:
        """Certified isolated kernel flops for this combination's
        schedule (0.0 when unmeasured — always sound)."""
        table = self.floors.get(seg_name)
        if not table:
            return 0.0
        return table.get(
            clause_schedule(clause, self.fields[seg_name]), 0.0)


def tune_segments(db, cfg, shape, segs, space: Dict[str, Tuple],
                  executor, top_k: int = 2,
                  use_cache: bool = True) -> KernelTuning:
    """Run the inner kernel sweep for every segment and rank schedules.

    Per segment: enumerate the schedule grid over the union of its ops'
    tuned fields, score each schedule as ``sum_op(count * time)`` from
    the per-op measurements, keep the ``top_k`` cheapest.  Schedules
    with any failed op variant are excluded (ComPar rejects failed
    combinations); a segment whose schedules ALL failed stays
    unrestricted — degraded, loud, never wrong.
    """
    out = KernelTuning()
    # measure each distinct op once (segments share op measurements)
    all_ops: Dict[str, int] = {}
    seg_ops: Dict[str, Dict[str, int]] = {}
    for seg in segs:
        ops = segment_ops(cfg, shape, seg)
        seg_ops[seg.name] = ops
        for op in ops:
            all_ops[op] = 1
    measured: Dict[str, Dict[str, Dict]] = {}
    n_timed = n_cached = n_failed = 0
    for op in sorted(all_ops):
        res, t, c = measure_op(db, op, cfg, shape, space, executor,
                               use_cache=use_cache)
        measured[op] = res
        n_timed += t
        n_cached += c
        n_failed += sum(1 for e in res.values() if e["status"] != "done")

    per_op_best = {
        op: min((e["time_s"], k) for k, e in res.items()
                if e["status"] == "done")[1]
        for op, res in measured.items()
        if any(e["status"] == "done" for e in res.values())}

    per_segment: Dict[str, Dict[str, int]] = {}
    has_flops = hasattr(executor, "hw")
    from repro.models.context import SegmentClause
    default = SegmentClause()
    for seg in segs:
        ops = seg_ops[seg.name]
        if not ops:
            continue
        fields = tuple(sorted({f for op in ops for f in OP_FIELDS[op]}))
        values = [tuple(space.get(f) or (getattr(default, f),))
                  for f in fields]
        ranked: List[Tuple[float, str]] = []
        floors: Dict[str, float] = {}
        n_sched = 0
        for point in itertools.product(*values):
            sched = dict(zip(fields, point))
            skey = schedule_key(sched)
            n_sched += 1
            cost = flops = 0.0
            ok = True
            for op, count in ops.items():
                vkey = schedule_key(
                    {f: sched[f] for f in OP_FIELDS[op]})
                e = measured[op].get(vkey)
                if e is None or e["status"] != "done":
                    ok = False
                    break
                cost += count * float(e["time_s"])
                flops += count * float(e.get("flops") or 0.0)
            if not ok:
                continue
            ranked.append((cost, skey))
            if has_flops:
                floors[skey] = flops
        if not ranked:
            log.warning("kernel tuning: every schedule of segment %s "
                        "failed — leaving it unrestricted", seg.name)
            per_segment[seg.name] = {"schedules": n_sched, "kept": n_sched}
            continue
        ranked.sort()                       # (cost, key): deterministic ties
        keep = {k for _, k in ranked[:max(1, int(top_k))]}
        out.fields[seg.name] = fields
        out.surviving[seg.name] = keep
        if floors:
            out.floors[seg.name] = floors
        per_segment[seg.name] = {"schedules": n_sched, "kept": len(keep)}

    out.report = {
        "n_variants": sum(len(r) for r in measured.values()),
        "n_timed": n_timed,
        "n_cached": n_cached,
        "n_failed": n_failed,
        "top_k": int(top_k),
        "per_op_best": per_op_best,
        "per_segment": per_segment,
    }
    return out
