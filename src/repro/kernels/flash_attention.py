"""Pallas TPU flash-attention (forward) kernel.

Layout: q (B, H, Sq, D); k/v (B, KV, Sk, D) — head-major so each grid cell
streams contiguous (S, D) tiles HBM->VMEM.  Grid: (B, H, nq, nk) with the
k-block axis innermost (sequential on TPU), carrying the online-softmax
state (acc, m, l) in VMEM scratch.  GQA is handled in the k/v index_map
(query head h reads kv head h // G).  Causal and sliding-window masks are
applied in-kernel; fully-masked blocks are neutralized multiplicatively
(no -inf/-inf pitfalls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)

    iq = pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    jk = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= iq >= jk
    if window:
        mask &= iq - jk < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)       # (bq, bk)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 1024,
                        interpret: bool = True):
    """q: (B,H,Sq,D); k/v: (B,KV,Sk,D) -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
