"""Pallas TPU flash-decode kernel: one-token attention over a long KV cache.

Layout: q (B, H, D); k/v (B, KV, Smax, D) head-major.  Grid (B, H, nk)
streams the KV cache in ``block_k`` tiles, carrying online-softmax state in
VMEM scratch.  The token position ``pos`` arrives as a (1, 1) int32 array
(read from VMEM) and masks out not-yet-written cache slots.  Emits the attention
output and, optionally, per-(head) LSE so sequence-sharded shards can be
combined with a single ``psum`` (see ``repro.serve``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
            acc_ref, m_ref, l_ref, *, scale, block_k, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (1, D) row
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    jk = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = jk <= pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)[0]
        lse_ref[0, 0] = (m_ref[0, 0] + jnp.log(l[0, 0]))


def flash_decode_fwd(q, k, v, pos, *, block_k: int = 1024,
                     interpret: bool = True, return_lse: bool = False):
    """q: (B,H,D); k/v: (B,KV,Smax,D); pos scalar int32 -> (B,H,D)."""
    B, H, D = q.shape
    KV, Smax = k.shape[1], k.shape[2]
    G = H // KV
    block_k = min(block_k, Smax)
    assert Smax % block_k == 0
    nk = Smax // block_k
    q4 = q[:, :, None, :]                               # (B,H,1,D)
    pos_arr = jnp.full((1, 1), pos, jnp.int32)
    kern = functools.partial(_kernel, scale=D ** -0.5,
                             block_k=block_k, nk=nk)
    out, lse = pl.pallas_call(
        kern,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (0, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik: (b, h // G, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q4, k, v)
    return (out, lse) if return_lse else out
