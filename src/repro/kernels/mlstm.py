"""Pallas TPU kernel for the chunkwise-parallel mLSTM.

Same math as ``repro.models.xlstm.mlstm_chunk`` for a single (batch, head):
intra-chunk quadratic attention with log-gated decay + inter-chunk matrix
state (C, n, m) carried in VMEM scratch across the sequential chunk axis.
Grid: (B, H, nc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LOG_EPS = -30.0


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
            C_ref, n_ref, m_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (c, dh) pre-scaled
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)              # (1, c) row vectors
    lf = lf_ref[0, 0].astype(jnp.float32)

    b = jnp.cumsum(lf, axis=-1)                        # (1, c)
    total = b[0, chunk - 1]
    m_prev = m_ref[0, 0]

    D = li[0][None, :] + b[0][:, None] - b[0][None, :]   # (c, c)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.where(mask, D, LOG_EPS)
    m_state = m_prev + b[0]                            # (c,)
    m_j = jnp.maximum(jnp.max(D, axis=-1), m_state)    # (c,)
    S = jnp.exp(D - m_j[:, None]) * jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    state_w = jnp.exp(m_state - m_j)                   # (c,)
    num = jax.lax.dot_general(S, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32) \
        + state_w[:, None] * jax.lax.dot_general(
            q, C_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    den_dot = (q @ n_ref[0]) * state_w + jnp.sum(S, axis=-1)
    den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_j))
    o_ref[0, 0] = (num / den[:, None]).astype(o_ref.dtype)

    # ---- state update ----
    k_w_log = li[0] + (total - b[0])                   # (c,)
    m_new = jnp.maximum(m_prev + total, jnp.max(k_w_log))
    carry_w = jnp.exp(m_prev + total - m_new)
    k_w = jnp.exp(k_w_log - m_new)                     # (c,)
    C_ref[...] = carry_w * C_ref[...] + jax.lax.dot_general(
        k * k_w[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[0] = carry_w * n_ref[0] + jnp.sum(k * k_w[:, None], axis=0)
    m_ref[0, 0] = m_new


def mlstm_chunkwise_fwd(q, k, v, li, lf, *, chunk: int = 256,
                        interpret: bool = True):
    """q,k,v: (B,H,S,dh) f32 (q pre-scaled); li,lf: (B,H,S) -> h (B,H,S,dh)."""
    B, H, S, dh = q.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    li4 = li[:, :, None, :]                            # (B,H,1,S)
    lf4 = lf[:, :, None, :]
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, ic: (b, h, 0, ic)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, ic: (b, h, 0, ic)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, dh),
                               lambda b, h, ic: (b, h, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li4, lf4)
