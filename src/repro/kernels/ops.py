"""jit-ready wrappers around the Pallas kernels (model-layout adapters).

Every op is differentiable via ``jax.custom_vjp``: forward runs the Pallas
kernel, backward runs the vjp of the pure-jnp reference (chunked where
memory matters).  On a real TPU deployment the backward would also be a
Pallas kernel; on this CPU container kernels execute in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode_fwd
from repro.kernels.mlstm import mlstm_chunkwise_fwd
from repro.kernels.rglru import rglru_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 1024,
                    interpret: bool = True):
    """Model layout: q (B,S,H,D); k/v (B,S,KV,D) -> (B,S,H,D)."""

    def _run(q, k, v):
        qh = jnp.moveaxis(q, 2, 1)                     # (B,H,S,D)
        kh = jnp.moveaxis(k, 2, 1)
        vh = jnp.moveaxis(v, 2, 1)
        o = flash_attention_fwd(qh, kh, vh, causal=causal, window=window,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)
        return jnp.moveaxis(o, 1, 2)

    def _ref(q, k, v):
        from repro.models.attention import chunked_attention
        S, Sk = q.shape[1], k.shape[1]
        return chunked_attention(
            q, k, v, pos_q=jnp.arange(S), pos_k=jnp.arange(Sk),
            window=window, q_chunk=block_q)

    @jax.custom_vjp
    def fa(q, k, v):
        return _run(q, k, v)

    def fa_fwd(q, k, v):
        return _run(q, k, v), (q, k, v)

    def fa_bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(_ref, q, k, v)
        return vjp(g)

    fa.defvjp(fa_fwd, fa_bwd)
    return fa(q, k, v)


def flash_decode(q, k_cache, v_cache, pos, *, block_k: int = 1024,
                 interpret: bool = True, return_lse: bool = False):
    """Model layout: q (B,H,D); caches (B,Smax,KV,D)."""
    kh = jnp.moveaxis(k_cache, 2, 1)                   # (B,KV,Smax,D)
    vh = jnp.moveaxis(v_cache, 2, 1)
    return flash_decode_fwd(q, kh, vh, pos, block_k=block_k,
                            interpret=interpret, return_lse=return_lse)


def rglru(log_a, b, *, chunk: int = 256, interpret: bool = True):
    """log_a, b: (B,S,dr) -> h (B,S,dr) f32."""

    @jax.custom_vjp
    def op(log_a, b):
        return rglru_fwd(log_a, b, chunk=chunk, interpret=interpret)

    def op_fwd(log_a, b):
        return op(log_a, b), (log_a, b)

    def op_bwd(res, g):
        log_a, b = res
        _, vjp = jax.vjp(R.rglru_ref, log_a, b)
        return vjp(g)

    op.defvjp(op_fwd, op_bwd)
    return op(log_a, b)


def mlstm_chunkwise(q, k, v, li, lf, *, chunk: int = 256,
                    interpret: bool = True):
    """q,k,v: (B,H,S,dh) f32 (q pre-scaled); li,lf: (B,H,S) -> (B,H,S,dh)."""

    def _ref(q, k, v, li, lf):
        from repro.models.xlstm import mlstm_chunk
        B, H, S, dh = q.shape
        c = min(chunk, S)
        while S % c:
            c -= 1
        nc = S // c
        rs = lambda t: jnp.moveaxis(
            t.reshape(*t.shape[:2], nc, c, *t.shape[3:]), 2, 0)
        state0 = (jnp.zeros((B, H, dh, dh), jnp.float32),
                  jnp.zeros((B, H, dh), jnp.float32),
                  jnp.zeros((B, H), jnp.float32))

        def step(state, inp):
            h, new = mlstm_chunk(*inp, state)
            return new, h
        _, hs = jax.lax.scan(step, state0,
                             (rs(q), rs(k), rs(v), rs(li), rs(lf)))
        return jnp.moveaxis(hs, 0, 2).reshape(B, H, S, dh)

    @jax.custom_vjp
    def op(q, k, v, li, lf):
        return mlstm_chunkwise_fwd(q, k, v, li, lf, chunk=chunk,
                                   interpret=interpret)

    def op_fwd(q, k, v, li, lf):
        return op(q, k, v, li, lf), (q, k, v, li, lf)

    def op_bwd(res, g):
        _, vjp = jax.vjp(_ref, *res)
        return vjp(g)

    op.defvjp(op_fwd, op_bwd)
    return op(q, k, v, li, lf)


def rmsnorm(x, scale, *, eps: float = 1e-6, interpret: bool = True):
    """x: (..., d) -> fused rmsnorm."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])

    @jax.custom_vjp
    def op(x2, scale):
        return rmsnorm_fwd(x2, scale, eps=eps, interpret=interpret)

    def op_fwd(x2, scale):
        return op(x2, scale), (x2, scale)

    def op_bwd(res, g):
        x2, scale = res
        _, vjp = jax.vjp(lambda x, s: R.rmsnorm_ref(x, s, eps), x2, scale)
        return vjp(g)

    op.defvjp(op_fwd, op_bwd)
    return op(x2, scale).reshape(shp)
