"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,Sq,D), k/v: (B,KV,Sk,D) -> (B,H,Sq,D). f32 math."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    iq = jnp.arange(Sq)[:, None]
    ik = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= iq >= ik
    if window:
        m &= iq - ik < window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def flash_decode_ref(q, k, v, pos):
    """q: (B,H,D); k/v: (B,Smax,KV,D); pos scalar -> (B,H,D)."""
    B, H, D = q.shape
    Smax, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    m = jnp.arange(Smax) <= pos
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def rglru_ref(log_a, b):
    """h_t = exp(log_a_t) * h_{t-1} + b_t over axis 1. (B,S,dr) f32."""
    def combine(x, y):
        la1, b1 = x
        la2, b2 = y
        return la1 + la2, jnp.exp(la2) * b1 + b2
    _, h = jax.lax.associative_scan(
        combine, (log_a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    return h


def mlstm_ref(q, k, v, li, lf):
    """Fully-recurrent stabilized mLSTM oracle (step by step).

    q,k,v: (B,H,S,dh) f32 (q pre-scaled); li,lf: (B,H,S) f32.
    Returns h: (B,H,S,dh).
    """
    B, H, S, dh = q.shape

    def step(state, t):
        C, n, m = state
        lf_t, li_t = lf[:, :, t], li[:, :, t]
        m_new = jnp.maximum(lf_t + m, li_t)
        f = jnp.exp(lf_t + m - m_new)
        i = jnp.exp(li_t - m_new)
        C = f[..., None, None] * C \
            + i[..., None, None] * (k[:, :, t, :, None] * v[:, :, t, None, :])
        n = f[..., None] * n + i[..., None] * k[:, :, t]
        num = jnp.einsum("bhd,bhde->bhe", q[:, :, t], C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, :, t], n)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    state = (jnp.zeros((B, H, dh, dh), jnp.float32),
             jnp.zeros((B, H, dh), jnp.float32),
             jnp.zeros((B, H), jnp.float32))
    _, hs = jax.lax.scan(step, state, jnp.arange(S))
    return jnp.moveaxis(hs, 0, 2)                      # (B,H,S,dh)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
