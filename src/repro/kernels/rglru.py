"""Pallas TPU kernel for the RG-LRU linear recurrence.

Computes ``h_t = exp(log_a_t) * h_{t-1} + b_t`` along the sequence.  The
sequence is tiled into chunks (sequential grid axis); within a chunk the
recurrence is closed-form:

    h_j = exp(cum_j) * h0 + sum_{l<=j} exp(cum_j - cum_l) * b_l

with ``cum = cumsum(log_a)``.  Since ``log_a <= 0`` and ``j >= l``, every
exponent is <= 0 — numerically stable without rescaling.  The chunk carry
``h0`` lives in VMEM scratch.  Feature dim is tiled independently
(parallel grid axes B x nd; sequential axis nc last).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(la_ref, b_ref, o_ref, h0_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h0_ref[...] = jnp.zeros_like(h0_ref)

    la = la_ref[0].astype(jnp.float32)                 # (c, bd)
    b = b_ref[0].astype(jnp.float32)
    cum = jnp.cumsum(la, axis=0)                       # (c, bd)
    # T[j, l, d] = exp(cum_j - cum_l) for l <= j else 0
    diff = cum[:, None, :] - cum[None, :, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    T = jnp.where(mask[..., None], jnp.exp(diff), 0.0)
    h = jnp.einsum("jld,ld->jd", T, b) + jnp.exp(cum) * h0_ref[...]
    o_ref[0] = h.astype(o_ref.dtype)
    h0_ref[...] = h[-1:]


def rglru_fwd(log_a, b, *, chunk: int = 256, block_d: int = 128,
              interpret: bool = True):
    """log_a, b: (B, S, dr) -> h: (B, S, dr), f32 math."""
    B, S, dr = log_a.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    block_d = min(block_d, dr)
    while dr % block_d:
        block_d -= 1
    nc, nd = S // chunk, dr // block_d
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((1, chunk, block_d), lambda ib, idd, ic: (ib, ic, idd)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda ib, idd, ic: (ib, ic, idd)),
        out_shape=jax.ShapeDtypeStruct((B, S, dr), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
