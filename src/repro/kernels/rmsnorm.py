"""Pallas TPU fused RMSNorm kernel (rows tiled, full feature dim in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (br, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                interpret: bool = True):
    """x: (N, d); scale: (d,) -> (N, d)."""
    N, d = x.shape
    br = min(block_rows, N)
    while N % br:
        br -= 1
    kern = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(N // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, scale[None, :])
