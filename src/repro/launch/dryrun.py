import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**input_specs).compile()`` must succeed on
the single-pod (16,16) mesh and the 2-pod (2,16,16) mesh for every cell,
and the compiled artifact yields the roofline terms (EXPERIMENTS §Roofline).

Results are written incrementally to a JSON file; already-done cells are
skipped on restart (the DB Continue mode, applied to the dry-run itself).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCHS, SHAPES, get_arch, get_shape, shape_applies
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.combinator import GlobalKnobs
from repro.core.executor import analyze_compiled, deadline, CombinationFailed
from repro.core.plan import Plan, uniform_plan
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.models.context import SegmentClause


def default_plan(cfg: ArchConfig, shape: ShapeConfig) -> Plan:
    """The a-priori 'single best compiler' baseline plan per cell
    (what a practitioner would pick without ComParX's sweep)."""
    if shape.kind == "train":
        clause = SegmentClause(remat="dots", kernel="xla")
        knobs = GlobalKnobs(microbatches=1, donate=True,
                            opt_state_dtype="bfloat16" if cfg.is_moe
                            else "float32")
        if cfg.is_moe:
            return uniform_plan(
                cfg, "expert_par",
                frozenset({"tp_attention", "fsdp_dense", "2d_experts"}),
                clause, knobs)
        return uniform_plan(cfg, "hybrid2d", frozenset({"shard_vocab"}),
                            clause, knobs)
    clause = SegmentClause(remat="none", kernel="xla")
    if cfg.is_moe:
        return uniform_plan(
            cfg, "expert_par",
            frozenset({"tp_attention", "fsdp_dense", "2d_experts"}),
            clause)
    return uniform_plan(cfg, "tensor_par", frozenset({"shard_vocab"}),
                        clause)


def input_specs(arch: str, shape_name: Optional[str] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name or "train_4k")
    if shape.kind == "train":
        from repro.train.step import batch_specs
        return {"batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        from repro.serve.step import prefill_input_specs
        return {"batch": prefill_input_specs(cfg, shape)}
    from repro.serve.step import decode_input_specs
    return decode_input_specs(cfg, shape)


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               plan: Optional[Plan] = None, verbose: bool = True):
    """Build + lower + compile one cell. Returns (lowered, compiled)."""
    plan = plan or default_plan(cfg, shape)
    from repro.models.params import abstract_params, param_pspecs
    from repro.models.model import model_specs, cache_specs
    from repro.train.step import (abstract_train_state, make_train_step)
    from repro.serve.step import (cache_shardings, decode_input_specs,
                                  make_decode_step, make_prefill,
                                  prefill_input_specs)

    from repro.core.executor import _mesh_scope
    with _mesh_scope(mesh):
        if shape.kind == "train":
            step, sh = make_train_step(cfg, mesh, plan, interpret=False)
            params, opt = abstract_train_state(cfg, plan)
            batch = input_specs(cfg.name, shape.name)["batch"]
            jitted = jax.jit(
                step,
                in_shardings=(sh["params"], sh["opt"], None),
                out_shardings=(sh["params"], sh["opt"], None),
                donate_argnums=(0, 1) if plan.knobs.donate else ())
            lowered = jitted.lower(params, opt, batch)
        elif shape.kind == "prefill":
            fn, sh = make_prefill(cfg, mesh, plan, interpret=False)
            from repro.models.params import abstract_params
            params = abstract_params(model_specs(cfg))
            batch = prefill_input_specs(cfg, shape)
            jitted = jax.jit(fn, in_shardings=(sh["params"], None))
            lowered = jitted.lower(params, batch)
        else:
            fn, sh = make_decode_step(cfg, mesh, plan, interpret=False)
            params = abstract_params(model_specs(cfg))
            caches = cache_specs(cfg, shape.global_batch, shape.seq_len)
            csh = cache_shardings(cfg, shape, mesh, plan)
            ins = decode_input_specs(cfg, shape)
            jitted = jax.jit(
                fn, in_shardings=(sh["params"], csh, None, None),
                donate_argnums=(1,))
            lowered = jitted.lower(params, caches, ins["tokens"], ins["pos"])
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             plan: Optional[Plan] = None, timeout_s: int = 1800,
             verbose: bool = True) -> Dict:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    if not shape_applies(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip",
                "reason": "full-attention arch; long_500k needs "
                          "sub-quadratic attention (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with deadline(timeout_s):
            lowered, compiled = lower_cell(cfg, shape, mesh, plan,
                                           verbose=verbose)
            terms = analyze_compiled(lowered, compiled, mesh_chips(mesh))
            mem_txt = str(compiled.memory_analysis())
    except Exception as e:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "fail", "elapsed_s": time.time() - t0,
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "chips": mesh_chips(mesh), "status": "ok",
           "elapsed_s": round(time.time() - t0, 1),
           "cost": terms.as_dict(),
           "detail": terms.detail, "dominant": terms.dominant}
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} "
              f"({'2x16x16' if multi_pod else '16x16'}): "
              f"compute={terms.compute_s:.4f}s memory={terms.memory_s:.4f}s "
              f"collective={terms.collective_s:.4f}s "
              f"dominant={terms.dominant} "
              f"bytes/dev={terms.bytes_per_device/2**30:.2f}GiB "
              f"[{rec['elapsed_s']}s]")
        print(f"  memory_analysis: {mem_txt[:300]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--plan", default=None,
                    help="path to a Plan json (default: per-cell baseline)")
    args = ap.parse_args()

    plan = Plan.load(args.plan) if args.plan else None
    results = {}
    if os.path.exists(args.out):          # Continue mode
        with open(args.out) as f:
            results = json.load(f)

    cells = []
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        key = f"{a}|{s}|{'multi' if mp else 'single'}"
        if key in results and results[key].get("status") in ("ok", "skip"):
            print(f"[dryrun] {key}: cached ({results[key]['status']})")
            continue
        results[key] = run_cell(a, s, multi_pod=mp, plan=plan,
                                timeout_s=args.timeout)
        with open(args.out, "w") as f:      # incremental commit
            json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skip")
    n_fail = sum(1 for r in results.values() if r["status"] == "fail")
    print(f"[dryrun] done: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        for k, r in results.items():
            if r["status"] == "fail":
                print(f"  FAIL {k}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
