"""Mesh construction for the production pods and local tests.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The multi-pod mesh adds a
leading DCN-connected ``pod`` axis that only ever carries data-parallel
all-reduces; all tensor/expert collectives stay intra-pod on ICI — this is
the property that scales the design past 1000 nodes.
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                     # older jax: meshes are Auto-typed
    AxisType = None


def _mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Best-effort local mesh from however many devices exist."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return _mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    return int(mesh.devices.size) if mesh is not None else 1
