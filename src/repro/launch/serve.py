"""Serving driver: continuous-batching greedy decoding under a ComParX
plan (CPU-runnable with --smoke).

Thin CLI over :class:`repro.serve.engine.ServeEngine` and
:class:`repro.serve.registry.PlanRegistry`.  The plan resolves in order:
``--plan`` file > ``--registry-db`` lookup (keyed by the *actual*
``--batch``/``--cache-len`` serving shape, nearest-traffic-shape
fallback) > the built-in default plan.

Usage:
  python -m repro.launch.serve --arch granite-8b --smoke --tokens 32
  python -m repro.launch.serve --arch stablelm-3b --smoke --batch 4 \\
      --cache-len 64 --registry-db /tmp/registry.db --requests 6
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_arch
from repro.core.plan import Plan
from repro.serve.engine import Request, ServeEngine
from repro.serve.registry import PlanRegistry, serving_shape


def synthetic_requests(n: int, vocab: int, *, prompt_len: int,
                       tokens: int, seed: int):
    """Deterministic seeded request stream (varying prompts/lengths)."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        p = max(1, prompt_len + int(rng.randint(-1, 2)))
        prompt = tuple(int(t) for t in rng.randint(0, vocab, size=p))
        reqs.append(Request(rid=f"r{i}", prompt=prompt,
                            max_new_tokens=tokens))
    return reqs


def resolve_plan(cfg, shape, *, plan_path=None, registry_db=None):
    """--plan file > registry lookup (nearest shape) > default plan."""
    if plan_path:
        return Plan.load(plan_path), f"file:{plan_path}"
    if registry_db:
        entry = PlanRegistry(registry_db).lookup(cfg, shape)
        if entry is None:
            raise SystemExit(
                f"[serve] no plan registered for {cfg.name} "
                f"{shape.kind}:{shape.seq_len}x{shape.global_batch} in "
                f"{registry_db} — run a sweep with registry= first "
                f"(python -m repro.serve.registry)")
        src = "registry" if entry.exact else f"registry~{entry.shape}"
        return entry.plan, src
    from repro.launch.dryrun import default_plan
    return default_plan(cfg, shape), "default"


def serve(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.launch.serve")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="slot capacity (the compiled batch)")
    ap.add_argument("--tokens", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--plan", default=None, help="plan JSON file")
    ap.add_argument("--registry-db", default=None,
                    help="resolve the plan from this PlanRegistry DB")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--max-active", type=int, default=None,
                    help="admission throttle (1 = sequential baseline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    # the serving shape IS the CLI's deployment: --cache-len x --batch
    shape = serving_shape(args.batch, args.cache_len)
    plan, src = resolve_plan(cfg, shape, plan_path=args.plan,
                             registry_db=args.registry_db)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"cache={args.cache_len} plan={src}")

    engine = ServeEngine(cfg, plan, capacity=args.batch,
                         cache_len=args.cache_len, seed=args.seed)
    reqs = synthetic_requests(args.requests, cfg.vocab_size,
                              prompt_len=args.prompt_len,
                              tokens=args.tokens, seed=args.seed)
    done = engine.run(reqs, max_active=args.max_active)
    for r in reqs:
        c = done[r.rid]
        print(f"[serve] {r.rid}: prompt={c.prompt_len} "
              f"-> {len(c.tokens)} tokens ({c.finish_reason}) "
              f"{c.tokens[:8]}{'...' if len(c.tokens) > 8 else ''}")
    print(f"[serve] {engine.stats.summary()}")
    return done


if __name__ == "__main__":
    serve()
