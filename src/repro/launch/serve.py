"""Serving driver: batched greedy decoding with a KV cache / recurrent
state under a ComParX plan (CPU-runnable with --smoke).

Usage:
  python -m repro.launch.serve --arch granite-8b --smoke --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_shape
from repro.core.plan import Plan
from repro.launch.dryrun import default_plan
from repro.models.model import init_cache, model_specs
from repro.models.params import init_params
from repro.serve.step import make_decode_step


def serve(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--plan", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = get_shape("decode_32k").smoke()
    plan = Plan.load(args.plan) if args.plan else default_plan(cfg, shape)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"cache={args.cache_len}")

    params = init_params(model_specs(cfg), jax.random.key(args.seed))
    step, _ = make_decode_step(cfg, None, plan)
    step = jax.jit(step, donate_argnums=(1,))
    caches = init_cache(cfg, args.batch, args.cache_len)
    tokens = jnp.zeros((args.batch,), jnp.int32)

    out = []
    t0 = time.perf_counter()
    for pos in range(args.tokens):
        tokens, logits, caches = step(params, caches, tokens,
                                      jnp.int32(pos))
        out.append(tokens)
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0
    seqs = jnp.stack(out, axis=1)
    tps = args.batch * args.tokens / dt
    print(f"[serve] generated {args.tokens} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({tps:.1f} tok/s)")
    print(f"[serve] sample: {seqs[0][:16].tolist()}")
    return seqs


if __name__ == "__main__":
    serve()
