"""Production training driver.

Wires together: config registry -> ComParX plan (tuned or baseline) ->
jitted train step -> deterministic resumable data -> async atomic
checkpoints -> heartbeat/failure handling.

Fault tolerance contract (1000+ node design):
* restart-from-latest is the default (``--resume auto``) — a requeued
  SLURM job continues exactly (data + RNG are step-indexed);
* checkpoints are atomic + keep-N, written async off the critical path;
* a missed heartbeat (straggling host) is surfaced via a watchdog so the
  scheduler can requeue; on this single-host container the watchdog just
  logs;
* elastic: ``--mesh`` may differ between runs — restore re-shards.

Usage:
  python -m repro.launch.train --arch granite-8b --smoke --steps 50
  python -m repro.launch.train --arch xlstm-125m --steps 200 --plan plan.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_arch, get_shape
from repro.configs.base import ShapeConfig
from repro.core.plan import Plan
from repro.data.pipeline import SyntheticLM
from repro.launch.dryrun import default_plan
from repro.launch.mesh import make_test_mesh
from repro.train.step import init_train_state, jit_train_step


class Heartbeat:
    """Watchdog hook: on a pod, each host posts a heartbeat and the
    launcher requeues stragglers; standalone it records step latencies."""

    def __init__(self, warn_factor: float = 3.0):
        self.warn_factor = warn_factor
        self.history = []

    def beat(self, step: int, dt: float):
        self.history.append(dt)
        med = float(np.median(self.history[-20:]))
        if len(self.history) > 5 and dt > self.warn_factor * med:
            print(f"[heartbeat] step {step}: straggler suspected "
                  f"({dt:.2f}s vs median {med:.2f}s)")


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--plan", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "never"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=100)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    if args.smoke:
        cfg, shape = cfg.smoke(), shape.smoke()
    if args.batch or args.seq:
        shape = ShapeConfig(shape.name + "-cli",
                            args.seq or shape.seq_len,
                            args.batch or shape.global_batch, shape.kind)

    plan = Plan.load(args.plan) if args.plan else default_plan(cfg, shape)
    mesh = None if len(jax.devices()) == 1 else make_test_mesh(
        data=len(jax.devices()))
    print(f"[train] arch={cfg.name} shape={shape.name} "
          f"devices={len(jax.devices())}")
    print("[train] plan:\n" + plan.describe())

    step_fn, shardings = jit_train_step(cfg, mesh, plan,
                                        peak_lr=args.lr,
                                        warmup=args.warmup)
    params, opt = init_train_state(cfg, plan, jax.random.key(args.seed))
    data = SyntheticLM(cfg, shape, seed=args.seed)
    store = CheckpointStore(
        args.ckpt_dir or f"/tmp/repro_ckpt_{cfg.name}", keep=3)
    start = 0
    if args.resume == "auto" and store.latest_step() is not None:
        start, state, extra = store.restore(
            {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        data.restore(extra["data"])
        print(f"[train] resumed from step {start}")

    hb = Heartbeat()
    losses = []
    for step in range(start, args.steps):
        batch = data.batch_at(step)
        data.state.step = step + 1
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["total_loss"])
        dt = time.perf_counter() - t0
        hb.beat(step, dt)
        losses.append(float(metrics["total_loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss={losses[-1]:.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            store.save_async(step + 1, {"params": params, "opt": opt},
                             extra={"data": data.snapshot(),
                                    "plan": plan.to_json()})
    store.wait()
    if losses:
        print(f"[train] final loss {losses[-1]:.4f} "
              f"(start {losses[0]:.4f}); checkpoints: {store.steps()}")
    else:
        print(f"[train] nothing to do (resumed at step {start} "
              f">= {args.steps}); checkpoints: {store.steps()}")
    return losses


if __name__ == "__main__":
    train()
