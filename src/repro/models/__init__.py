from repro.models.context import ModelContext, SegmentClause  # noqa: F401
from repro.models.model import (  # noqa: F401
    forward, decode_step, model_specs, cache_specs, init_cache,
    segment_names, SEG_EMBED, SEG_HEAD,
)
from repro.models.params import (  # noqa: F401
    ParamSpec, init_params, abstract_params, param_pspecs, param_count,
)
