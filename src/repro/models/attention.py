"""Attention: GQA/MHA with RoPE (full/2d), causal + sliding-window masks.

Three execution paths, selectable via the segment clause (the ComParX
"directive clause" analogue):
  * ``naive``   — full score matrix; oracle + tiny shapes.
  * ``chunked`` — q-chunked streaming attention (pure-XLA flash analogue);
                  memory O(block_q x S) instead of O(S^2).
  * ``pallas``  — TPU flash-attention kernel (``repro.kernels``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.context import ModelContext
from repro.models.layers import apply_rope, dense
from repro.models.params import ParamSpec

NEG_INF = -1e30


def attn_specs(cfg: ArchConfig, dtype: Optional[str] = None):
    dt = dtype or cfg.dtype
    d, H, KV, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    s = d ** -0.5
    return {
        "wq": ParamSpec((d, H, D), ("embed", "heads", "head_dim"), "normal", s, dt),
        "wk": ParamSpec((d, KV, D), ("embed", "kv_heads", "head_dim"), "normal", s, dt),
        "wv": ParamSpec((d, KV, D), ("embed", "kv_heads", "head_dim"), "normal", s, dt),
        "wo": ParamSpec((H, D, d), ("heads", "head_dim", "embed"), "normal",
                        (H * D) ** -0.5, dt),
    }


# --- core math ---------------------------------------------------------------

def _mask(pos_q, pos_k, window: int):
    m = pos_q[:, None] >= pos_k[None, :]
    if window:
        m &= pos_q[:, None] - pos_k[None, :] < window
    return m


def naive_attention(q, k, v, *, pos_q, pos_k, window: int = 0):
    """q: (B,Sq,H,D), k/v: (B,Sk,KV,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    m = _mask(pos_q, pos_k, window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_attention(q, k, v, *, pos_q, pos_k, window: int = 0,
                      q_chunk: int = 512):
    """Streaming q-chunked attention (same math as naive, bounded memory).

    For sliding-window attention only a (window + q_chunk)-wide K slice is
    read per chunk, making long-context local attention O(S * window).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    if Sq % q_chunk or Sq <= q_chunk:
        return naive_attention(q, k, v, pos_q=pos_q, pos_k=pos_k,
                               window=window)
    nq = Sq // q_chunk
    k_span = min(Sk, window + q_chunk) if window else Sk
    k_span = max(k_span, q_chunk)
    # when the window covers the whole K range, per-chunk dynamic slices
    # would be full copies of K/V every chunk — read K/V directly instead
    # (EXPERIMENTS §Perf, starcoder2 cell: 3x memory-term reduction)
    slice_k = bool(window) and k_span < Sk

    def one(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        pq = jax.lax.dynamic_slice_in_dim(pos_q, i * q_chunk, q_chunk, axis=0)
        if slice_k:
            start = jnp.clip(i * q_chunk + q_chunk - k_span, 0, Sk - k_span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, k_span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, k_span, axis=1)
            pk = jax.lax.dynamic_slice_in_dim(pos_k, start, k_span, axis=0)
        else:
            ks, vs, pk = k, v, pos_k
        return naive_attention(qs, ks, vs, pos_q=pq, pos_k=pk, window=window)

    out = jax.lax.map(one, jnp.arange(nq))            # (nq, B, c, H, D)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, D)


def decode_attention(q1, k_cache, v_cache, pos, *, window: int = 0,
                     upcast: bool = True):
    """One-token attention against a KV cache.

    q1: (B,H,D); caches: (B,Smax,KV,D); pos: scalar index of the new
    token, or a (B,) vector of per-row positions (the serving engine's
    continuous batching — each slot decodes its own stream).
    Reads the full cache (memory-roofline bound); the Pallas flash-decode
    kernel implements the same contraction blocked over Smax.

    ``upcast=True`` converts the cache to f32 before the contractions (the
    naive baseline: 3x HBM traffic at bf16 caches).  ``upcast=False`` reads
    bf16 directly with f32 accumulation (``preferred_element_type``) —
    identical math on the MXU, a third of the traffic (EXPERIMENTS §Perf).
    """
    B, H, D = q1.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q1.reshape(B, KV, G, D)
    if upcast:
        s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                       k_cache.astype(jnp.float32))
    else:
        s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(k_cache.dtype), k_cache,
                       preferred_element_type=jnp.float32)
    s = s * (D ** -0.5)
    ks = jnp.arange(Smax)
    if jnp.ndim(pos) == 0:
        m = ks <= pos
        if window:
            m &= ks > pos - window
        m = m[None, None, None]
    else:
        # per-row positions (continuous batching): row b masks against
        # its own pos, so its output depends on row b's inputs alone
        m = ks[None, :] <= pos[:, None]                 # (B,Smax)
        if window:
            m &= ks[None, :] > pos[:, None] - window
        m = m[:, None, None, :]
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if upcast:
        o = jnp.einsum("bkgs,bskd->bkgd", p,
                       v_cache.astype(jnp.float32))
    else:
        o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    return o.reshape(B, H, D).astype(q1.dtype)


# --- module-level apply ------------------------------------------------------

def _project_qkv(p, x, cfg: ArchConfig, ctx: ModelContext, positions):
    q = dense(x, p["wq"])                              # (B,S,H,D)
    k = dense(x, p["wk"])                              # (B,S,KV,D)
    v = dense(x, p["wv"])
    q = apply_rope(q, positions, cfg.rope)
    k = apply_rope(k, positions, cfg.rope)
    q = ctx.constrain(q, ("batch", "seq", "heads", None))
    k = ctx.constrain(k, ("batch", "seq", "kv_heads", None))
    v = ctx.constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def attn_apply(p, x, cfg: ArchConfig, ctx: ModelContext, positions):
    """Full-sequence attention (train / prefill). x: (B,S,d_model)."""
    q, k, v = _project_qkv(p, x, cfg, ctx, positions)
    cl = ctx.clause
    if cl.kernel == "pallas":
        from repro import kernels as kops
        o = kops.flash_attention(
            q, k, v, causal=True, window=cfg.window_size,
            block_q=cl.block_q, block_k=cl.block_k, interpret=ctx.interpret)
    else:
        o = chunked_attention(q, k, v, pos_q=positions, pos_k=positions,
                              window=cfg.window_size, q_chunk=cl.block_q)
    o = ctx.constrain(o, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshd,hde->bse", o, p["wo"]).astype(x.dtype)
    return ctx.constrain(y, ("batch", "seq", "embed"))


def attn_cache_spec(cfg: ArchConfig, batch: int, smax: int):
    """Abstract KV cache shapes for one layer."""
    KV, D = cfg.num_kv_heads, cfg.head_dim_
    cache_len = min(smax, cfg.window_size) if cfg.window_size else smax
    shp = (batch, cache_len, KV, D)
    return {"k": jax.ShapeDtypeStruct(shp, jnp.dtype(cfg.dtype)),
            "v": jax.ShapeDtypeStruct(shp, jnp.dtype(cfg.dtype))}


def _seq_sharded(ctx: ModelContext, cache) -> bool:
    """True when the provider shards the KV cache's seq dim."""
    if ctx.rules.mesh is None:
        return False
    ps = ctx.rules.pspec(("batch", "kv_seq", "kv_heads", None),
                         cache["k"].shape)
    parts = list(ps)
    return len(parts) > 1 and parts[1] is not None


def attn_decode_shardmap(q, k, v, cache, pos, ctx: ModelContext):
    """Sequence-sharded KV decode via shard_map (EXPERIMENTS §Perf cell C).

    The pure-pjit path dus-updates a cache whose seq dim is sharded; the
    SPMD partitioner handles that with *involuntary full rematerialization*
    (replicate -> update -> reshard) every layer — catastrophic traffic.
    Here each model shard keeps its local (B_l, S_l, KV, D) cache block,
    updates it only when ``pos`` lands in its range (collective-free), and
    attention is combined across shards with a single log-sum-exp psum —
    the same combine contract as the Pallas flash-decode kernel's LSE
    output (tests/test_kernels.py::test_flash_decode_lse_combine).
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime.sharding import shard_map_compat

    mesh = ctx.rules.mesh
    axis_sizes = ctx.rules.axis_sizes
    tp = axis_sizes["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    B, Smax, KV, D = cache["k"].shape
    H = q.shape[1]
    G = H // KV
    S_l = Smax // tp
    dp = 1
    for a in batch_axes:
        dp *= axis_sizes[a]
    b_ax = batch_axes if batch_axes and B % dp == 0 else None

    def local(q, k, v, ck, cv, pos):
        rank = jax.lax.axis_index("model")
        lo = rank * S_l
        slot = jnp.clip(pos - lo, 0, S_l - 1)
        in_range = (pos >= lo) & (pos < lo + S_l)
        ck_u = jax.lax.dynamic_update_slice_in_dim(ck, k[:, None], slot,
                                                   axis=1)
        cv_u = jax.lax.dynamic_update_slice_in_dim(cv, v[:, None], slot,
                                                   axis=1)
        ck = jnp.where(in_range, ck_u, ck)
        cv = jnp.where(in_range, cv_u, cv)
        # local partial attention with global-position mask
        qg = q.reshape(q.shape[0], KV, G, D)
        s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(ck.dtype), ck,
                       preferred_element_type=jnp.float32) * (D ** -0.5)
        ks = lo + jnp.arange(S_l)
        s = jnp.where((ks <= pos)[None, None, None], s, NEG_INF)
        m_l = jnp.max(s, axis=-1, keepdims=True)
        p_l = jnp.exp(s - m_l)
        l_l = jnp.sum(p_l, axis=-1, keepdims=True)
        o_l = jnp.einsum("bkgs,bskd->bkgd", p_l.astype(cv.dtype), cv,
                         preferred_element_type=jnp.float32)
        # distributed softmax combine (log-sum-exp over the model axis)
        # m_l / l_l keep the trailing singleton (B,KV,G,1) for broadcast
        m_g = jax.lax.pmax(m_l, "model")
        l_g = jax.lax.psum(jnp.exp(m_l - m_g) * l_l, "model")
        o = jax.lax.psum(o_l * jnp.exp(m_l - m_g), "model")
        o = o / jnp.maximum(l_g, 1e-30)
        return o.reshape(q.shape[0], H, D).astype(q.dtype), ck, cv

    cache_spec = P(b_ax, "model", None, None)
    o, ck, cv = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(b_ax, None, None), P(b_ax, None, None),
                  P(b_ax, None, None), cache_spec, cache_spec, P()),
        out_specs=(P(b_ax, None, None), cache_spec, cache_spec),
        check=False,
    )(q, k, v, cache["k"], cache["v"], pos)
    return o, {"k": ck, "v": cv}


def attn_decode(p, x1, cache, pos, cfg: ArchConfig, ctx: ModelContext):
    """One-token decode. x1: (B,d_model); cache: {"k","v"} (B,Smax,KV,D).

    ``pos`` is a scalar (the classic batched loop: every row at the same
    position) or a ``(B,)`` vector of per-row positions (continuous
    batching).  The vector path writes the cache with a per-row one-hot
    select and masks per row, so row ``b`` of every output is a function
    of row ``b``'s inputs alone — the serving engine's byte-identity
    contract.  The pallas flash-decode and shard_map kernels take a
    single scalar position, so vector-pos calls use the XLA path.
    """
    q = dense(x1, p["wq"])                             # (B,H,D)
    k = dense(x1, p["wk"])                             # (B,KV,D)
    v = dense(x1, p["wv"])
    q = apply_rope(q, pos, cfg.rope)
    k = apply_rope(k, pos, cfg.rope)
    vector_pos = jnp.ndim(pos) > 0
    if (not vector_pos and ctx.clause.decode_shardmap
            and not cfg.window_size and _seq_sharded(ctx, cache)):
        o, new_cache = attn_decode_shardmap(q, k, v, cache, pos, ctx)
        y = jnp.einsum("bhd,hde->be", o, p["wo"]).astype(x1.dtype)
        return ctx.constrain(y, ("batch", "embed")), new_cache
    cache_len = cache["k"].shape[1]
    slot = pos % cache_len if cfg.window_size else pos  # ring buffer if windowed
    if vector_pos:
        # per-row write: a dynamic_update_slice needs one shared scalar
        # slot, so select row b's slot with a one-hot mask instead
        hit = jnp.arange(cache_len)[None, :] == slot[:, None]   # (B,Smax)
        k_cache = jnp.where(hit[:, :, None, None], k[:, None], cache["k"])
        v_cache = jnp.where(hit[:, :, None, None], v[:, None], cache["v"])
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k[:, None], slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v[:, None], slot, axis=1)
    k_cache = ctx.constrain(k_cache, ("batch", "kv_seq", "kv_heads", None))
    v_cache = ctx.constrain(v_cache, ("batch", "kv_seq", "kv_heads", None))
    if cfg.window_size:
        # ring buffer: all valid entries attendable except future ones
        o = decode_attention(q, k_cache, v_cache,
                             jnp.minimum(pos, cache_len - 1), window=0,
                             upcast=ctx.clause.cache_upcast)
    elif ctx.clause.kernel == "pallas" and not vector_pos:
        from repro import kernels as kops
        o = kops.flash_decode(q, k_cache, v_cache, pos,
                              block_k=ctx.clause.block_k,
                              interpret=ctx.interpret)
    else:
        o = decode_attention(q, k_cache, v_cache, pos,
                             upcast=ctx.clause.cache_upcast)
    y = jnp.einsum("bhd,hde->be", o, p["wo"]).astype(x1.dtype)
    y = ctx.constrain(y, ("batch", "embed"))
    return y, {"k": k_cache, "v": v_cache}
