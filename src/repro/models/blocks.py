"""Block-kind dispatcher: specs / apply / decode / cache-spec per kind.

Kinds: ``attn`` (attention + dense FFN), ``attn_moe`` (attention + MoE FFN),
``rec`` (RG-LRU + FFN), ``mlstm``, ``slstm``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import mlp as M
from repro.models import moe as MOE
from repro.models import rglru as R
from repro.models import xlstm as X
from repro.models.context import ModelContext
from repro.models.layers import norm_apply, norm_specs

BLOCK_KINDS = ("attn", "attn_moe", "rec", "mlstm", "slstm")


def block_specs(kind: str, cfg: ArchConfig):
    dt = cfg.dtype
    d = cfg.d_model
    if kind in ("attn", "attn_moe"):
        s = {"ln1": norm_specs(d, cfg.norm, dt),
             "attn": A.attn_specs(cfg),
             "ln2": norm_specs(d, cfg.norm, dt)}
        s["ffn"] = MOE.moe_specs(cfg) if kind == "attn_moe" \
            else M.mlp_specs(cfg)
        return s
    if kind == "rec":
        return {"rec": R.rec_specs(cfg),
                "ln2": norm_specs(d, cfg.norm, dt),
                "ffn": M.mlp_specs(cfg)}
    if kind == "mlstm":
        return X.mlstm_specs(cfg)
    if kind == "slstm":
        return X.slstm_specs(cfg)
    raise ValueError(kind)


def block_apply(kind: str, p, x, cfg: ArchConfig, ctx: ModelContext,
                positions):
    """Full-sequence forward. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_moe"):
        h = norm_apply(p["ln1"], x, cfg.norm)
        x = x + A.attn_apply(p["attn"], h, cfg, ctx, positions)
        h = norm_apply(p["ln2"], x, cfg.norm)
        if kind == "attn_moe":
            y, aux = MOE.moe_apply(p["ffn"], h, cfg, ctx)
        else:
            y = M.mlp_apply(p["ffn"], h, cfg, ctx)
        x = x + y
        return x, aux
    if kind == "rec":
        x = R.rec_apply(p["rec"], x, cfg, ctx)
        h = norm_apply(p["ln2"], x, cfg.norm)
        x = x + M.mlp_apply(p["ffn"], h, cfg, ctx)
        return x, aux
    if kind == "mlstm":
        return X.mlstm_apply(p, x, cfg, ctx), aux
    if kind == "slstm":
        return X.slstm_apply(p, x, cfg, ctx), aux
    raise ValueError(kind)


def block_cache_spec(kind: str, cfg: ArchConfig, batch: int, smax: int):
    """Abstract per-layer decode cache/state."""
    if kind in ("attn", "attn_moe"):
        return A.attn_cache_spec(cfg, batch, smax)
    if kind == "rec":
        return R.rec_state_spec(cfg, batch)
    if kind == "mlstm":
        return X.mlstm_state_spec(cfg, batch)
    if kind == "slstm":
        return X.slstm_state_spec(cfg, batch)
    raise ValueError(kind)


def block_decode(kind: str, p, x1, cache, pos, cfg: ArchConfig,
                 ctx: ModelContext):
    """One-token decode. x1: (B,d). Returns (x1, new_cache)."""
    if kind in ("attn", "attn_moe"):
        h = norm_apply(p["ln1"], x1[:, None], cfg.norm)[:, 0]
        y, new_cache = A.attn_decode(p["attn"], h, cache, pos, cfg, ctx)
        x1 = x1 + y
        h = norm_apply(p["ln2"], x1[:, None], cfg.norm)
        if kind == "attn_moe":
            y, _ = MOE.moe_apply(p["ffn"], h, cfg, ctx)
        else:
            y = M.mlp_apply(p["ffn"], h, cfg, ctx)
        return x1 + y[:, 0], new_cache
    if kind == "rec":
        x1, new_cache = R.rec_decode(p["rec"], x1, cache, cfg, ctx)
        h = norm_apply(p["ln2"], x1[:, None], cfg.norm)
        y = M.mlp_apply(p["ffn"], h, cfg, ctx)
        return x1 + y[:, 0], new_cache
    if kind == "mlstm":
        return X.mlstm_decode(p, x1, cache, cfg, ctx)
    if kind == "slstm":
        return X.slstm_decode(p, x1, cache, cfg, ctx)
    raise ValueError(kind)
