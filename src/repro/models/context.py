"""Execution context threaded through model apply functions.

``SegmentClause`` is ComParX's analogue of an OpenMP ``parallel for``
directive clause set: per-segment execution hyper-parameters that the
Combinator sweeps and the Optimal Plan Generator fuses.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Tuple

from repro.runtime.sharding import Rules


@dataclass(frozen=True)
class SegmentClause:
    remat: str = "none"          # none | dots | full
    kernel: str = "xla"          # xla | pallas
    block_q: int = 512           # attention q-chunk (xla) / q-block (pallas)
    block_k: int = 1024          # pallas k-block
    scan_unroll: int = 1         # layer-scan unroll factor
    mlstm_chunk: int = 256       # chunk length for mLSTM / linear-recurrence
    # --- beyond-paper clauses (EXPERIMENTS §Perf) ---
    moe_dispatch: str = "sorted"  # sorted | a2a (shard_map expert-parallel)
    cache_upcast: bool = True     # f32-upcast KV reads (naive) vs bf16 reads
    decode_shardmap: bool = False  # shard_map seq-sharded KV decode (LSE)

    def key(self) -> str:
        return (f"remat={self.remat},kernel={self.kernel},bq={self.block_q},"
                f"bk={self.block_k},unroll={self.scan_unroll},"
                f"mc={self.mlstm_chunk},md={self.moe_dispatch},"
                f"cu={int(self.cache_upcast)},"
                f"dsm={int(self.decode_shardmap)}")


@dataclass(frozen=True)
class ModelContext:
    rules: Rules = field(default_factory=Rules.null)
    clause: SegmentClause = SegmentClause()
    moe_groups: int = 1          # GShard-style dispatch groups
    interpret: bool = True       # pallas interpret mode (CPU container)
    decode: bool = False

    def with_(self, **kw) -> "ModelContext":
        return replace(self, **kw)

    def constrain(self, x, axes: Tuple[str, ...]):
        return self.rules.constrain(x, axes)
