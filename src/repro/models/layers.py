"""Shared layer primitives: norms, activations, RoPE, dense application."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


# --- activations -----------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu, "tanh": jnp.tanh}[name]


# --- norms ------------------------------------------------------------------

def norm_specs(d: int, kind: str, dtype: str):
    s = {"scale": ParamSpec((d,), ("embed",), "ones", dtype=dtype)}
    if kind == "layernorm":
        s["bias"] = ParamSpec((d,), ("embed",), "zeros", dtype=dtype)
    return s


def norm_apply(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        xf = xf - mu
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if kind == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --- dense -----------------------------------------------------------------

def dense(x, w):
    """Contract the last dim of x with the first dim of w.

    Output stays in the activation dtype (bf16 on the TPU target): the MXU
    accumulates in f32 internally, and keeping dot outputs bf16 halves the
    bytes the remat policy saves per layer (see EXPERIMENTS §Perf).
    """
    return jax.lax.dot_general(
        x, w.astype(x.dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
    )


# --- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, rotary_dim: Optional[int] = None,
               base: float = 10000.0):
    rd = rotary_dim or head_dim
    exps = jnp.arange(0, rd, 2, dtype=jnp.float32) / rd
    return 1.0 / (base ** exps)                       # (rd//2,)


def apply_rope(x, positions, style: str = "full", base: float = 10000.0):
    """x: (..., S, H, D) or (..., H, D) with scalar positions.

    ``style``: ``full`` rotates all of D; ``2d`` (chatglm) rotates only the
    first half of D; ``none`` is identity.
    """
    if style == "none":
        return x
    D = x.shape[-1]
    rd = D // 2 if style == "2d" else D
    rd -= rd % 2
    inv = rope_freqs(D, rd, base)                     # (rd//2,)
    theta = positions[..., None].astype(jnp.float32) * inv    # (..., rd//2)
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    # broadcast over the head axis, which sits between seq and head_dim
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rot = jnp.stack([r1, r2], axis=-1).reshape(*xr.shape)
    return jnp.concatenate([rot.astype(x.dtype), x[..., rd:]], axis=-1)


# --- temporal conv (decode-friendly) ----------------------------------------

def causal_conv1d(x, w, state=None):
    """Depthwise causal conv along the seq axis.

    x: (B, S, D); w: (W, D).  If ``state`` (B, W-1, D) is given, it is the
    decode-time history; returns (y, new_state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (W - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+W-1, D)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        y = y + xp[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    new_state = xp[:, -(W - 1):, :] if W > 1 else pad
    return y.astype(x.dtype), new_state
