"""Causal LM loss: cross-entropy with z-loss and optional masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, targets, *, z_loss: float = 1e-4, mask=None):
    """logits: (..., V) f32; targets: (...,) int32.

    Returns (mean loss, metrics dict).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None],
                             axis=-1)[..., 0]
    nll = lse - ll
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is not None:
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(m), 1.0)
        loss = jnp.sum(per_tok * m) / denom
        acc_n = jnp.sum((jnp.argmax(logits, -1) == targets) * m) / denom
    else:
        loss = jnp.mean(per_tok)
        acc_n = jnp.mean(jnp.argmax(logits, -1) == targets)
    return loss, {"nll": jnp.mean(nll), "z_loss": jnp.mean(zl),
                  "accuracy": acc_n}
