"""Dense FFN (optionally gated / GLU)."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.context import ModelContext
from repro.models.layers import act_fn, dense
from repro.models.params import ParamSpec


def mlp_specs(cfg: ArchConfig, d_ff: int = 0, dtype=None):
    dt = dtype or cfg.dtype
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {"wi": ParamSpec((d, f), ("embed", "ffn"), "normal", d ** -0.5, dt),
         "wo": ParamSpec((f, d), ("ffn", "embed"), "normal", f ** -0.5, dt)}
    if cfg.glu:
        s["wg"] = ParamSpec((d, f), ("embed", "ffn"), "normal", d ** -0.5, dt)
    return s


def mlp_apply(p, x, cfg: ArchConfig, ctx: ModelContext):
    act = act_fn(cfg.act)
    h = dense(x, p["wi"])
    h_axes = ("batch", "seq", "ffn") if h.ndim == 3 else ("batch", "ffn")
    h = ctx.constrain(h, h_axes)
    if cfg.glu:
        h = act(dense(x, p["wg"])) * h
    else:
        h = act(h)
    y = dense(h, p["wo"])
    axes = ("batch", "seq", "embed") if y.ndim == 3 else ("batch", "embed")
    return ctx.constrain(y, axes)
