"""Full causal-LM assembly: embedding -> scanned block groups -> head.

The layer stack is split into homogeneous *scan groups*
(``ArchConfig.stack_plan``); each group is one ComParX **segment** with its
own :class:`ModelContext` (sharding rules + execution clause).  Groups with
``repeats > 1`` are executed with ``jax.lax.scan`` over stacked parameters
so the HLO stays compact at any depth.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ScanGroup
from repro.models.blocks import (block_apply, block_cache_spec, block_decode,
                                 block_specs)
from repro.models.context import ModelContext
from repro.models.layers import norm_apply, norm_specs
from repro.models.params import ParamSpec, stack_specs

SEG_EMBED = "embed"
SEG_HEAD = "head"


def segment_names(cfg: ArchConfig):
    return ([SEG_EMBED]
            + [f"g{i}" for i in range(len(cfg.stack_plan()))]
            + [SEG_HEAD])


def model_specs(cfg: ArchConfig):
    d, V = cfg.d_model, cfg.vocab_size
    specs = {SEG_EMBED: {"tok": ParamSpec((V, d), ("vocab", "embed"),
                                          "normal", 1.0, cfg.dtype)}}
    for gi, group in enumerate(cfg.stack_plan()):
        gspec = {}
        for j, kind in enumerate(group.pattern):
            bs = block_specs(kind, cfg)
            gspec[f"b{j}"] = stack_specs(bs, group.repeats) \
                if group.repeats > 1 else bs
        specs[f"g{gi}"] = gspec
    head: Dict[str, object] = {"norm": norm_specs(d, cfg.norm, cfg.dtype)}
    if not cfg.tie_embeddings:
        head["out"] = ParamSpec((d, V), ("embed", "vocab"), "normal",
                                d ** -0.5, cfg.dtype)
    specs[SEG_HEAD] = head
    return specs


def _ctx_for(ctxs, seg: str) -> ModelContext:
    if isinstance(ctxs, ModelContext):
        return ctxs
    return ctxs.get(seg, ctxs.get("*", ModelContext()))


def _remat(fn, clause):
    if clause.remat == "dots":
        # no-batch-dims policy: saves weight matmuls but NOT attention
        # score matrices (saving those costs O(S^2) HBM per layer)
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if clause.remat == "full":
        return jax.checkpoint(fn)
    return fn


def _run_group(x, gparams, group: ScanGroup, cfg, ctx, positions):
    """Forward one scan group. Returns (x, aux)."""
    def superblock(x, layer_params):
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(group.pattern):
            x, a = block_apply(kind, layer_params[f"b{j}"], x, cfg, ctx,
                               positions)
            aux = aux + a
        return x, aux
    fn = _remat(superblock, ctx.clause)
    if group.repeats == 1:
        return fn(x, gparams)
    def step(carry, layer_params):
        x, aux = carry
        x, a = fn(x, layer_params)
        return (x, aux + a), None
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               gparams, unroll=ctx.clause.scan_unroll)
    return x, aux


def embed_tokens(params, tokens, cfg: ArchConfig, ctx: ModelContext):
    x = jnp.take(params[SEG_EMBED]["tok"], tokens, axis=0)
    axes = ("batch", "seq", "embed") if x.ndim == 3 else ("batch", "embed")
    return ctx.constrain(x, axes)


def lm_head(params, x, cfg: ArchConfig, ctx: ModelContext):
    x = norm_apply(params[SEG_HEAD]["norm"], x, cfg.norm)
    w = params[SEG_EMBED]["tok"].T if cfg.tie_embeddings \
        else params[SEG_HEAD]["out"]
    logits = jnp.einsum("...d,dv->...v", x, w,
                        preferred_element_type=jnp.float32)
    axes = ("batch", "seq", "vocab") if logits.ndim == 3 \
        else ("batch", "vocab")
    return ctx.constrain(logits, axes)


def forward(params, batch, cfg: ArchConfig, ctxs):
    """Train/prefill forward. batch: {"tokens" | "embeds", ...}.

    Returns (logits (B,S,V) f32, aux_loss scalar).
    """
    ectx = _ctx_for(ctxs, SEG_EMBED)
    if "embeds" in batch:          # vlm/audio stub frontend
        x = ectx.constrain(batch["embeds"], ("batch", "seq", "embed"))
    else:
        x = embed_tokens(params, batch["tokens"], cfg, ectx)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    aux = jnp.zeros((), jnp.float32)
    for gi, group in enumerate(cfg.stack_plan()):
        seg = f"g{gi}"
        x, a = _run_group(x, params[seg], group, cfg, _ctx_for(ctxs, seg),
                          positions)
        aux = aux + a
    logits = lm_head(params, x, cfg, _ctx_for(ctxs, SEG_HEAD))
    return logits, aux


# --- decode ------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, batch: int, smax: int):
    """Abstract decode cache for the whole stack (stacked per group)."""
    caches = {}
    for gi, group in enumerate(cfg.stack_plan()):
        gcache = {}
        for j, kind in enumerate(group.pattern):
            cs = block_cache_spec(kind, cfg, batch, smax)
            if group.repeats > 1:
                cs = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        (group.repeats,) + s.shape, s.dtype), cs)
            gcache[f"b{j}"] = cs
        caches[f"g{gi}"] = gcache
    return caches


def init_cache(cfg: ArchConfig, batch: int, smax: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, smax))


def decode_step(params, caches, tokens, pos, cfg: ArchConfig, ctxs):
    """One decoding step. tokens: (B,) int32; pos: scalar int32.

    Returns (logits (B,V) f32, new caches).
    """
    ectx = _ctx_for(ctxs, SEG_EMBED)
    x = embed_tokens(params, tokens, cfg, ectx)
    new_caches = {}
    for gi, group in enumerate(cfg.stack_plan()):
        seg = f"g{gi}"
        ctx = _ctx_for(ctxs, seg).with_(decode=True)
        gparams, gcache = params[seg], caches[seg]

        def superblock(x, layer_params, layer_cache):
            new_cache = {}
            for j, kind in enumerate(group.pattern):
                x, c = block_decode(kind, layer_params[f"b{j}"], x,
                                    layer_cache[f"b{j}"], pos, cfg, ctx)
                new_cache[f"b{j}"] = c
            return x, new_cache

        if group.repeats == 1:
            x, new_caches[seg] = superblock(x, gparams, gcache)
        else:
            def step(x, pc):
                lp, lc = pc
                x, nc = superblock(x, lp, lc)
                return x, nc
            x, new_caches[seg] = jax.lax.scan(
                step, x, (gparams, gcache),
                unroll=ctx.clause.scan_unroll)
    logits = lm_head(params, x, cfg, _ctx_for(ctxs, SEG_HEAD))
    return logits, new_caches
