"""Mixture-of-Experts layer: top-k router + grouped, sort-based dispatch.

Dispatch strategy (GShard-style groups, sort-based within a group):

1. tokens are partitioned into ``G`` groups (G = number of data shards, so
   each group's dispatch is shard-local work);
2. within a group, (token, expert) assignments are sorted by expert id and
   written into a per-expert capacity buffer ``(G, E, C, d)`` — no
   ``(T, E, C)`` one-hot tensor is ever materialized;
3. expert FFNs run as one batched einsum over the buffer (E shardable on
   the ``model`` axis = expert parallelism);
4. results are gathered back and combined with router weights.

Tokens beyond capacity ``C = cf * S_group * k / E`` are dropped (standard
capacity-factor semantics); the residual connection keeps them intact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.context import ModelContext
from repro.models.layers import act_fn, dense
from repro.models.params import ParamSpec


def moe_specs(cfg: ArchConfig, dtype=None):
    dt = dtype or cfg.dtype
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = {
        "router": ParamSpec((d, E), ("embed", "experts"), "normal",
                            d ** -0.5, "float32"),
        "wi": ParamSpec((E, d, f), ("experts", "embed", "expert_ffn"),
                        "normal", d ** -0.5, dt),
        "wo": ParamSpec((E, f, d), ("experts", "expert_ffn", "embed"),
                        "normal", f ** -0.5, dt),
    }
    if cfg.glu:
        s["wg"] = ParamSpec((E, d, f), ("experts", "embed", "expert_ffn"),
                            "normal", d ** -0.5, dt)
    return s


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(cfg.moe_capacity_factor * tokens_per_group
            * cfg.experts_per_token / cfg.num_experts)
    return max(8, min(c, tokens_per_group))


def _dispatch_group(xg, gates, idx, E: int, C: int):
    """One group's dispatch. xg: (S,d), gates/idx: (S,k).

    Returns (buffer (E, C+1, d), combine info). Slot C is the overflow bin.
    """
    S, d = xg.shape
    k = idx.shape[-1]
    flat_e = idx.reshape(-1)                          # (S*k,)
    order = jnp.argsort(flat_e, stable=True)          # sort by expert
    e_sorted = flat_e[order]
    tok_sorted = order // k
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(S * k, dtype=jnp.int32) - offsets[e_sorted]
    slot = jnp.where(pos < C, pos, C)                 # overflow -> bin C
    buf = jnp.zeros((E, C + 1, d), xg.dtype)
    buf = buf.at[e_sorted, slot].set(xg[tok_sorted], mode="drop")
    return buf, (e_sorted, slot, tok_sorted, order)


def _combine_group(out_buf, info, gates, S: int):
    """out_buf: (E, C+1, d) -> (S, d) weighted combine."""
    e_sorted, slot, tok_sorted, order = info
    k = gates.shape[-1]
    y = out_buf[e_sorted, slot]                       # (S*k, d)
    w_sorted = gates.reshape(-1)[order]
    keep = (slot < out_buf.shape[1] - 1).astype(y.dtype)
    y = y * (w_sorted * keep)[:, None]
    return jnp.zeros((S, out_buf.shape[-1]), y.dtype).at[tok_sorted].add(y)


def moe_apply(p, x, cfg: ArchConfig, ctx: ModelContext):
    """x: (B, S, d) -> (B, S, d). Dispatch strategy from the clause."""
    if ctx.clause.moe_dispatch == "a2a" and ctx.rules.mesh is not None \
            and "model" in ctx.rules.axis_sizes \
            and cfg.num_experts % ctx.rules.axis_sizes["model"] == 0:
        return moe_apply_a2a(p, x, cfg, ctx)
    return moe_apply_sorted(p, x, cfg, ctx)


def moe_apply_sorted(p, x, cfg: ArchConfig, ctx: ModelContext):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    G = min(ctx.moe_groups, T)
    while T % G:
        G -= 1
    Sg = T // G
    C = capacity(cfg, Sg)

    xf = x.reshape(G, Sg, d)
    xf = ctx.constrain(xf, ("batch", None, "embed"))
    logits = dense(xf, p["router"]).astype(jnp.float32)     # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                    # (G,Sg,k)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style), returned via ctx side channel
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / k
    aux = E * jnp.sum(me * ce)

    buf, info = jax.vmap(lambda xg, g, i: _dispatch_group(xg, g, i, E, C))(
        xf, gates, idx)
    buf = ctx.constrain(buf, ("batch", "experts", None, "embed"))

    act = act_fn(cfg.act)
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.glu:
        g = jnp.einsum("gecd,edf->gecf", buf, p["wg"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = act(g) * h
    else:
        h = act(h)
    h = ctx.constrain(h, ("batch", "experts", None, "expert_ffn"))
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out_buf = ctx.constrain(out_buf, ("batch", "experts", None, "embed"))

    y = jax.vmap(lambda ob, inf, g: _combine_group(ob, inf, g, Sg))(
        out_buf, info, gates)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = ctx.constrain(y, ("batch", "seq", "embed"))
    return y, aux


# ---------------------------------------------------------------------------
# Beyond-paper dispatch (EXPERIMENTS §Perf): shard_map expert parallelism.
#
# The sorted/einsum dispatch above leaves the token->expert routing to the
# SPMD partitioner, which materializes cross-shard gathers (collective-
# bound at 128-384 experts).  Here the routing is explicit: tokens are
# data-sharded and replicated over the model axis; each model shard owns
# E_local = E / tp experts, locally dispatches only the tokens routed to
# *its* experts (zero communication — tokens are already present), and the
# partial outputs are combined with a single psum over the model axis per
# layer.  Collective cost drops from O(buffer gathers) to one (T_local, d)
# all-reduce.
# ---------------------------------------------------------------------------

def moe_apply_a2a(p, x, cfg: ArchConfig, ctx: ModelContext):
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.runtime.sharding import shard_map_compat

    mesh = ctx.rules.mesh
    axis_sizes = ctx.rules.axis_sizes
    tp = axis_sizes["model"]
    E, k = cfg.num_experts, cfg.experts_per_token
    E_local = E // tp
    B, S, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    dp = 1
    for a in batch_axes:
        dp *= axis_sizes[a]
    # local token count per (pod,data) shard; replicated over model
    T_local = (B * S) // dp if B % dp == 0 or (B * S) % dp == 0 else B * S
    C = capacity(cfg, T_local)

    x_spec = P(batch_axes if B % dp == 0 else None, None, None)
    w_spec_i = P("model", None, None)      # (E, d, f) sharded on experts
    r_spec = P(None, None)                 # router replicated
    out_spec = x_spec

    def local_moe(xl, router, wi, wg, wo):
        # xl: (B_l, S, d); wi/wg/wo: (E_local, ...)
        Bl, Sl, dl = xl.shape
        T = Bl * Sl
        xf = xl.reshape(T, dl)
        logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)               # (T, k) global ids
        gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)
        rank = jax.lax.axis_index("model")
        lo = rank * E_local
        mine = (idx >= lo) & (idx < lo + E_local)          # (T, k)
        local_idx = jnp.where(mine, idx - lo, E_local)     # E_local = trash
        Cl = capacity(cfg, T)
        buf, info = _dispatch_group(xf, gates * mine, local_idx,
                                    E_local + 1, Cl)
        buf = buf[:E_local]                                # drop trash row
        act = act_fn(cfg.act)
        h = jnp.einsum("ecd,edf->ecf", buf, wi,
                       preferred_element_type=jnp.float32).astype(xl.dtype)
        if wg is not None:
            g = jnp.einsum("ecd,edf->ecf", buf, wg,
                           preferred_element_type=jnp.float32
                           ).astype(xl.dtype)
            h = act(g) * h
        else:
            h = act(h)
        ob = jnp.einsum("ecf,efd->ecd", h, wo,
                        preferred_element_type=jnp.float32).astype(xl.dtype)
        # pad the trash expert row back for combine indexing
        ob = jnp.concatenate(
            [ob, jnp.zeros((1,) + ob.shape[1:], ob.dtype)], axis=0)
        y = _combine_group(ob, info, gates * mine, T)
        # combine in the activation dtype: psum'ing bf16 partials halves
        # the per-layer collective bytes (EXPERIMENTS §Perf cell B)
        y = jax.lax.psum(y.astype(xl.dtype), "model")
        return y.reshape(Bl, Sl, dl)

    wg = p.get("wg")
    router = p["router"].astype(jnp.float32)
    fn = shard_map_compat(
        local_moe, mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec_i, w_spec_i if wg is not None
                  else P(), w_spec_i),
        out_specs=out_spec,
        check=False)
    if wg is None:
        fn_out = shard_map_compat(
            lambda xl, r, wi, wo: local_moe(xl, r, wi, None, wo),
            mesh=mesh, in_specs=(x_spec, r_spec, w_spec_i, w_spec_i),
            out_specs=out_spec, check=False)
        y = fn_out(x, router, p["wi"], p["wo"])
    else:
        y = fn(x, router, p["wi"], wg, p["wo"])
    # aux loss: recompute cheaply outside (replicated router math)
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax(
        (xf.astype(jnp.float32) @ router), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                          axis=1), axis=0) / k
    aux = E * jnp.sum(me * ce)
    return y, aux
