"""Parameter-spec system.

A model is declared as a pytree of :class:`ParamSpec` leaves (single source
of truth for shape, dtype, init and *logical* sharding axes).  From the spec
tree we derive:

* concrete initialized parameters      (``init_params``)
* abstract ``ShapeDtypeStruct`` params (``abstract_params`` — dry-run)
* ``PartitionSpec`` trees              (``param_pspecs`` — given provider rules)

Logical axis names used across the codebase:
``vocab, embed, heads, kv_heads, head_dim, ffn, experts, expert_ffn, rnn,
conv, layers`` (``layers`` is the scan-stack dim and is never sharded).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 1.0            # stddev for normal init
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            self.shape, self.logical_axes)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def stacked(self, n: int) -> "ParamSpec":
        """Add a leading scan ("layers") dim of size n."""
        return dataclasses.replace(
            self, shape=(n,) + tuple(self.shape),
            logical_axes=("layers",) + tuple(self.logical_axes))


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaf_key(root_key, path) -> jax.Array:
    # deterministic per-leaf key derived from the flattened path string
    name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    h = hash(name) % (2 ** 31 - 1)
    return jax.random.fold_in(root_key, h)


def init_params(specs, key):
    """Materialize a spec tree into concrete parameters."""
    def init_one(path, spec: ParamSpec):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.jdtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.jdtype)
        k = _leaf_key(key, path)
        return (jax.random.normal(k, spec.shape, jnp.float32) * spec.scale
                ).astype(spec.jdtype)
    return jax.tree_util.tree_map_with_path(init_one, specs,
                                            is_leaf=is_spec)


def abstract_params(specs):
    """Spec tree -> ShapeDtypeStruct tree (no allocation; for dry-run)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.jdtype), specs,
        is_leaf=is_spec)


def param_pspecs(specs, rules) -> object:
    """Spec tree -> PartitionSpec tree under ``rules``.

    ``rules`` is a :class:`repro.runtime.sharding.Rules` (maps logical axis
    name -> mesh axes with divisibility fallback).
    """
    return jax.tree.map(lambda s: rules.pspec(s.logical_axes, s.shape),
                        specs, is_leaf=is_spec)


def param_count(specs) -> int:
    import math
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(specs) -> int:
    import math
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) * s.jdtype.itemsize for s in leaves)


def stack_specs(specs, n: int):
    """Stack a block's spec tree along a new leading scan dim."""
    return jax.tree.map(lambda s: s.stacked(n), specs, is_leaf=is_spec)


def stack_params(param_list):
    """Stack a list of concrete per-layer param pytrees along dim 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *param_list)


# Convenience constructors -------------------------------------------------

def dense_spec(d_in: int, d_out: Tuple[int, ...], axes_in, axes_out,
               dtype: str, scale: Optional[float] = None) -> ParamSpec:
    """Weight (d_in, *d_out) with fan-in scaled normal init."""
    if scale is None:
        scale = d_in ** -0.5
    d_out = (d_out,) if isinstance(d_out, int) else tuple(d_out)
    axes_out = (axes_out,) if isinstance(axes_out, (str, type(None))) \
        else tuple(axes_out)
    return ParamSpec((d_in,) + d_out, (axes_in,) + axes_out,
                     "normal", scale, dtype)
