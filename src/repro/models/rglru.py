"""Griffin/RecurrentGemma recurrent block: gated branch x (conv + RG-LRU).

RG-LRU: ``h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)`` with
``a_t = exp(-c * softplus(L) * r_t)``, ``r_t/i_t`` input-dependent sigmoid
gates.  Train/prefill uses an associative scan (log-depth); decode is a
single-step update.  The Pallas kernel (``repro.kernels.rglru``) implements
the same recurrence as a blocked sequential in-VMEM scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.context import ModelContext
from repro.models.layers import causal_conv1d, dense, norm_apply, norm_specs
from repro.models.params import ParamSpec

RGLRU_C = 8.0


def rglru_dims(cfg: ArchConfig) -> int:
    return int(cfg.expand_factor * cfg.d_model)


def rec_specs(cfg: ArchConfig, dtype=None):
    dt = dtype or cfg.dtype
    d = cfg.d_model
    dr = rglru_dims(cfg)
    s = d ** -0.5
    sr = dr ** -0.5
    return {
        "ln": norm_specs(d, cfg.norm, dt),
        "w_gate": ParamSpec((d, dr), ("embed", "rnn"), "normal", s, dt),
        "w_x": ParamSpec((d, dr), ("embed", "rnn"), "normal", s, dt),
        "conv": ParamSpec((cfg.conv_width, dr), ("conv", "rnn"), "normal",
                          cfg.conv_width ** -0.5, dt),
        "w_a": ParamSpec((dr, dr), ("rnn", None), "normal", sr, "float32"),
        "w_i": ParamSpec((dr, dr), ("rnn", None), "normal", sr, "float32"),
        "lam": ParamSpec((dr,), ("rnn",), "ones", dtype="float32"),
        "w_out": ParamSpec((dr, d), ("rnn", "embed"), "normal", sr, dt),
    }


def rec_state_spec(cfg: ArchConfig, batch: int):
    dr = rglru_dims(cfg)
    return {
        "h": jax.ShapeDtypeStruct((batch, dr), jnp.dtype("float32")),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, dr),
                                     jnp.dtype(cfg.dtype)),
    }


def _rglru_coeffs(p, u):
    """u: (..., dr) post-conv input -> (a, b) recurrence coefficients f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"])
    i = jax.nn.sigmoid(uf @ p["w_i"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    # sqrt(1 - a^2) computed stably via expm1
    scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = scale * (i * uf)
    return log_a, b


def rglru_scan(a, b, h0=None):
    """Associative linear recurrence over axis 1. a,b: (B,S,dr)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh


def rec_apply(p, x, cfg: ArchConfig, ctx: ModelContext):
    """Full-sequence recurrent block (pre-norm residual). x: (B,S,d)."""
    xn = norm_apply(p["ln"], x, cfg.norm)
    gate = jax.nn.gelu(dense(xn, p["w_gate"]))
    u = dense(xn, p["w_x"])
    u, _ = causal_conv1d(u, p["conv"])
    log_a, b = _rglru_coeffs(p, u)
    if ctx.clause.kernel == "pallas":
        from repro import kernels as kops
        h = kops.rglru(log_a, b, chunk=ctx.clause.mlstm_chunk,
                       interpret=ctx.interpret)
    else:
        h = rglru_scan(jnp.exp(log_a), b)
    y = dense((h.astype(x.dtype) * gate), p["w_out"])
    y = ctx.constrain(y, ("batch", "seq", "embed"))
    return x + y


def rec_decode(p, x1, state, cfg: ArchConfig, ctx: ModelContext):
    """One-token recurrent step. x1: (B,d)."""
    xn = norm_apply(p["ln"], x1[:, None], cfg.norm)
    gate = jax.nn.gelu(dense(xn, p["w_gate"]))
    u = dense(xn, p["w_x"])
    u, new_conv = causal_conv1d(u, p["conv"], state["conv"])
    log_a, b = _rglru_coeffs(p, u)
    h = jnp.exp(log_a[:, 0]) * state["h"] + b[:, 0]
    y = dense((h[:, None].astype(x1.dtype) * gate), p["w_out"])[:, 0]
    return x1 + y, {"h": h, "conv": new_conv}
