"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

The mLSTM is a gated linear-attention cell with matrix state
``C (dk x dv)``; the chunkwise-parallel form (intra-chunk quadratic +
inter-chunk recurrent state) is the TPU-friendly formulation — the Pallas
kernel in ``repro.kernels.mlstm`` implements the same per-chunk math.
States are kept log-stabilized: semantic state is ``(C e^m, n e^m)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.context import ModelContext
from repro.models.layers import causal_conv1d, dense, norm_apply, norm_specs
from repro.models.params import ParamSpec

LOG_EPS = -30.0


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


# =====================================================================
# mLSTM
# =====================================================================

def mlstm_dims(cfg: ArchConfig):
    di = int(cfg.expand_factor * cfg.d_model)
    H = cfg.num_heads
    assert di % H == 0
    return di, H, di // H


def mlstm_specs(cfg: ArchConfig, dtype=None):
    dt = dtype or cfg.dtype
    d = cfg.d_model
    di, H, dh = mlstm_dims(cfg)
    s = d ** -0.5
    si = di ** -0.5
    return {
        "ln": norm_specs(d, cfg.norm, dt),
        "w_up": ParamSpec((d, 2 * di), ("embed", "rnn"), "normal", s, dt),
        "conv": ParamSpec((cfg.conv_width, di), ("conv", "rnn"), "normal",
                          cfg.conv_width ** -0.5, dt),
        "wq": ParamSpec((di, H, dh), ("rnn", "heads", "head_dim"), "normal", si, dt),
        "wk": ParamSpec((di, H, dh), ("rnn", "heads", "head_dim"), "normal", si, dt),
        "wv": ParamSpec((di, H, dh), ("rnn", "heads", "head_dim"), "normal", si, dt),
        "w_if": ParamSpec((d, 2, H), ("embed", None, "heads"), "normal", s, "float32"),
        "b_if": ParamSpec((2, H), (None, "heads"), "zeros", dtype="float32"),
        "gn": {"scale": ParamSpec((di,), ("rnn",), "ones", dtype=dt)},
        "w_down": ParamSpec((di, d), ("rnn", "embed"), "normal", si, dt),
    }


def mlstm_state_spec(cfg: ArchConfig, batch: int):
    di, H, dh = mlstm_dims(cfg)
    f32 = jnp.dtype("float32")
    dt = jnp.dtype(cfg.dtype)
    return {
        "C": jax.ShapeDtypeStruct((batch, H, dh, dh), f32),
        "n": jax.ShapeDtypeStruct((batch, H, dh), f32),
        "m": jax.ShapeDtypeStruct((batch, H), f32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1,
                                      int(cfg.expand_factor * cfg.d_model)), dt),
    }


def mlstm_chunk(q, k, v, li, lf, state):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,c,dh) f32 (q pre-scaled by dh**-0.5); li,lf: (B,H,c) f32;
    state: (C (B,H,dk,dv), n (B,H,dk), m (B,H)).
    Returns h (B,H,c,dh) and the new state.
    """
    C, n, m = state
    B, H, c, dh = q.shape
    b = jnp.cumsum(lf, axis=-1)                        # (B,H,c) inclusive
    total = b[..., -1:]                                # (B,H,1)
    # intra-chunk log decay matrix D[j,l] = li_l + b_j - b_l  (l <= j)
    D = li[..., None, :] + b[..., :, None] - b[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(mask, D, LOG_EPS)
    m_state = m[..., None] + b                         # (B,H,c)
    m_j = jnp.maximum(jnp.max(D, axis=-1), m_state)    # (B,H,c)
    S = jnp.exp(D - m_j[..., None]) * (q @ jnp.swapaxes(k, -1, -2))
    state_w = jnp.exp(m_state - m_j)                   # (B,H,c)
    num = S @ v + state_w[..., None] * (q @ C)
    den_dot = jnp.einsum("bhcd,bhd->bhc", q, n) * state_w \
        + jnp.sum(S, axis=-1)
    den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_j))
    h = num / den[..., None]
    # ---- state update ----
    k_w_log = li + (total - b)                         # decay k_j to chunk end
    m_new = jnp.maximum(m + total[..., 0],
                        jnp.max(k_w_log, axis=-1))     # (B,H)
    carry_w = jnp.exp(m + total[..., 0] - m_new)       # (B,H)
    k_w = jnp.exp(k_w_log - m_new[..., None])          # (B,H,c)
    C_new = carry_w[..., None, None] * C \
        + jnp.einsum("bhc,bhcd,bhce->bhde", k_w, k, v)
    n_new = carry_w[..., None] * n \
        + jnp.einsum("bhc,bhcd->bhd", k_w, k)
    return h, (C_new, n_new, m_new)


def _mlstm_qkvif(p, x, cfg: ArchConfig, conv_state=None):
    """Shared projection path. x: (B,S,d) -> q,k,v (B,H,S,dh), li/lf (B,H,S)."""
    di, H, dh = mlstm_dims(cfg)
    u = dense(x, p["w_up"])                            # (B,S,2*di)
    z, gate = jnp.split(u, 2, axis=-1)
    cz, new_conv = causal_conv1d(z, p["conv"], conv_state)
    cz = jax.nn.silu(cz)
    q = jnp.einsum("bsi,ihd->bhsd", cz, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsi,ihd->bhsd", cz, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsi,ihd->bhsd", z, p["wv"]).astype(jnp.float32)
    q = q * dh ** -0.5
    gif = jnp.einsum("bsd,dgh->bgsh", x.astype(jnp.float32), p["w_if"]) \
        + p["b_if"][None, :, None, :]
    li = jnp.swapaxes(gif[:, 0], 1, 2)                 # (B,H,S)
    lf = _logsigmoid(jnp.swapaxes(gif[:, 1], 1, 2))
    return q, k, v, li, lf, gate, new_conv, (di, H, dh)


def _mlstm_out(p, h, gate, x, cfg: ArchConfig):
    """h: (B,H,S,dh) -> residual output (B,S,d)."""
    B, H, S, dh = h.shape
    hb = jnp.moveaxis(h, 1, 2).reshape(B, S, H * dh).astype(x.dtype)
    hb = norm_apply(p["gn"], hb, "rmsnorm")
    hb = hb * jax.nn.silu(gate)
    return dense(hb, p["w_down"])


def mlstm_apply(p, x, cfg: ArchConfig, ctx: ModelContext):
    """Full-sequence mLSTM block (pre-norm residual)."""
    xin = x
    xn = norm_apply(p["ln"], x, cfg.norm)
    q, k, v, li, lf, gate, _, (di, H, dh) = _mlstm_qkvif(p, xn, cfg)
    B, _, S, _ = q.shape
    c = min(ctx.clause.mlstm_chunk, S)
    while S % c:
        c -= 1
    if ctx.clause.kernel == "pallas":
        from repro import kernels as kops
        h = kops.mlstm_chunkwise(q, k, v, li, lf, chunk=c,
                                 interpret=ctx.interpret)
    else:
        nc = S // c
        def step(state, inp):
            qc, kc, vc, lic, lfc = inp
            h, new = mlstm_chunk(qc, kc, vc, lic, lfc, state)
            return new, h
        rs = lambda t: jnp.moveaxis(
            t.reshape(*t.shape[:2], nc, c, *t.shape[3:]), 2, 0)
        state0 = (jnp.zeros((B, H, dh, dh), jnp.float32),
                  jnp.zeros((B, H, dh), jnp.float32),
                  jnp.zeros((B, H), jnp.float32))
        _, hs = jax.lax.scan(step, state0,
                             (rs(q), rs(k), rs(v), rs(li), rs(lf)))
        h = jnp.moveaxis(hs, 0, 2).reshape(B, H, S, dh)
    y = _mlstm_out(p, h, gate, x, cfg)
    y = ctx.constrain(y, ("batch", "seq", "embed"))
    return xin + y


def mlstm_decode(p, x1, state, cfg: ArchConfig, ctx: ModelContext):
    """One-token mLSTM step. x1: (B,d)."""
    xn = norm_apply(p["ln"], x1[:, None], cfg.norm)
    q, k, v, li, lf, gate, new_conv, _ = _mlstm_qkvif(
        p, xn, cfg, conv_state=state["conv"])
    h, (C, n, m) = mlstm_chunk(q, k, v, li, lf,
                               (state["C"], state["n"], state["m"]))
    y = _mlstm_out(p, h, gate, xn, cfg)[:, 0]
    new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    return x1 + y, new_state


# =====================================================================
# sLSTM
# =====================================================================

def slstm_dims(cfg: ArchConfig):
    H = cfg.num_heads
    assert cfg.d_model % H == 0
    return H, cfg.d_model // H


def slstm_specs(cfg: ArchConfig, dtype=None):
    dt = dtype or cfg.dtype
    d = cfg.d_model
    H, dh = slstm_dims(cfg)
    ff = max(64, int(round(d * 4 / 3 / 64)) * 64)
    s = d ** -0.5
    return {
        "ln": norm_specs(d, cfg.norm, dt),
        "conv": ParamSpec((cfg.conv_width, d), ("conv", "embed"), "normal",
                          cfg.conv_width ** -0.5, dt),
        "w": ParamSpec((d, 4, H, dh), ("embed", None, "heads", "head_dim"),
                       "normal", s, "float32"),
        "r": ParamSpec((H, 4, dh, dh), ("heads", None, "head_dim", None),
                       "normal", dh ** -0.5, "float32"),
        "b": ParamSpec((4, H, dh), (None, "heads", "head_dim"), "zeros",
                       dtype="float32"),
        "gn": {"scale": ParamSpec((d,), ("embed",), "ones", dtype=dt)},
        "w_up": ParamSpec((d, 2 * ff), ("embed", "ffn"), "normal", s, dt),
        "w_down": ParamSpec((ff, d), ("ffn", "embed"), "normal",
                            ff ** -0.5, dt),
    }


def slstm_state_spec(cfg: ArchConfig, batch: int):
    H, dh = slstm_dims(cfg)
    f32 = jnp.dtype("float32")
    return {
        "h": jax.ShapeDtypeStruct((batch, H, dh), f32),
        "c": jax.ShapeDtypeStruct((batch, H, dh), f32),
        "n": jax.ShapeDtypeStruct((batch, H, dh), f32),
        "m": jax.ShapeDtypeStruct((batch, H, dh), f32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1,
                                      cfg.d_model), jnp.dtype(cfg.dtype)),
    }


def _slstm_cell(zx, r, state):
    """zx: (B,4,H,dh) pre-activations from input; recurrent r: (H,4,dh,dh)."""
    h, c, n, m = state
    zr = jnp.einsum("bhe,hged->bghd", h, r)            # (B,4,H,dh)
    zi, zf, zz, zo = [zx[:, g] + zr[:, g] for g in range(4)]
    m_new = jnp.maximum(zf + m, zi)
    i = jnp.exp(zi - m_new)
    f = jnp.exp(zf + m - m_new)
    c_new = f * c + i * jnp.tanh(zz)
    n_new = jnp.maximum(f * n + i, 1e-6)
    h_new = jax.nn.sigmoid(zo) * c_new / n_new
    return h_new, (h_new, c_new, n_new, m_new)


def _slstm_gates(p, xn, conv_state=None):
    cz, new_conv = causal_conv1d(xn, p["conv"], conv_state)
    cz = jax.nn.silu(cz).astype(jnp.float32)
    zx = jnp.einsum("bsd,dghe->bsghe", cz, p["w"]) + p["b"]
    return zx, new_conv                                 # (B,S,4,H,dh)


def _slstm_out(p, h_seq, x, cfg):
    """h_seq: (B,S,H,dh) -> residual (B,S,d)."""
    B, S = h_seq.shape[:2]
    hb = h_seq.reshape(B, S, -1).astype(x.dtype)
    hb = norm_apply(p["gn"], hb, "rmsnorm")
    u, g = jnp.split(dense(hb, p["w_up"]), 2, axis=-1)
    return dense(jax.nn.gelu(g) * u, p["w_down"])


def slstm_apply(p, x, cfg: ArchConfig, ctx: ModelContext):
    """Full-sequence sLSTM block; the cell is inherently sequential."""
    xn = norm_apply(p["ln"], x, cfg.norm)
    zx, _ = _slstm_gates(p, xn)
    B, S = x.shape[:2]
    H, dh = slstm_dims(cfg)
    z0 = jnp.zeros((B, H, dh), jnp.float32)
    state0 = (z0, z0, jnp.full_like(z0, 1e-6), z0)
    def step(state, z_t):
        h_new, st = _slstm_cell(z_t, p["r"], state)
        return st, h_new
    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(zx, 1, 0))
    y = _slstm_out(p, jnp.moveaxis(hs, 0, 1), x, cfg)
    y = ctx.constrain(y, ("batch", "seq", "embed"))
    return x + y


def slstm_decode(p, x1, state, cfg: ArchConfig, ctx: ModelContext):
    xn = norm_apply(p["ln"], x1[:, None], cfg.norm)
    zx, new_conv = _slstm_gates(p, xn, conv_state=state["conv"])
    h_new, (h, c, n, m) = _slstm_cell(
        zx[:, 0], p["r"], (state["h"], state["c"], state["n"], state["m"]))
    y = _slstm_out(p, h_new[:, None], xn, cfg)[:, 0]
    return x1 + y, {"h": h, "c": c, "n": n, "m": m, "conv": new_conv}
