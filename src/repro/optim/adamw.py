"""AdamW with global-norm clipping, cosine schedule, and optional
optimizer-state compression (m/v in bf16 — the distributed-optimization
knob that lets kimi-k2-1t fit 512 x 16 GB; see EXPERIMENTS §Dry-run)."""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: object                     # pytree like params
    v: object


def adamw_init(params, state_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_abstract_state(param_specs_or_params, state_dtype: str = "float32"):
    dt = jnp.dtype(state_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.dtype("int32")),
                      m=jax.tree.map(sds, param_specs_or_params),
                      v=jax.tree.map(sds, param_specs_or_params))


def cosine_lr(step, *, peak_lr: float, warmup: int = 100,
              total: int = 10000, min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, step / max(warmup, 1))
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, *,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: Optional[float] = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    step = state.step + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** sf
    bc2 = 1.0 - b2 ** sf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mh = mf / bc1
        vh = vf / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, AdamWState(step, new_m, new_v), metrics
