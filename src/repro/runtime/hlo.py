"""HLO-text analysis for the roofline model.

``compiled.cost_analysis()`` on XLA:CPU counts a ``while`` body **once**,
so any scanned program (layers, attention chunks, recurrent cells) is
massively under-reported.  This module parses the optimized HLO module
into its computations, builds the call graph (fusion ``calls=``, ``call``
``to_apply=``, ``while`` ``body=``/``condition=`` with
``known_trip_count``), and accumulates:

* **flops** — 2 * prod(result) * K for every ``dot`` (K from the lhs
  contracting dims), multiplied along the call graph by loop trip counts;
* **bytes** — every scheduled op's result bytes (fusion-internal ops
  excluded), x2 for write+read, x trip counts — an HBM-traffic estimator;
* **collective bytes** — per-chip bytes moved for all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute with ring
  factors, x trip counts.

All quantities are **per device**: the compiled module is the post-SPMD
per-partition program.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shapes_in(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    by_op: Dict[str, float] = field(default_factory=dict)
    # (callee, multiplier, include_bytes)
    edges: List[Tuple[str, float]] = field(default_factory=list)
    fusion_callees: List[str] = field(default_factory=list)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _split_operands(s: str) -> List[str]:
    """Split an operand list on top-level commas only: shapes and tuple
    types carry internal commas (``f32[64,128]{1,0} %x``), and older XLA
    prints operands with their full types inline."""
    out: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[({":
            depth += 1
        elif ch in "])}":
            depth -= 1
        elif ch == "," and depth == 0:
            tok = "".join(cur).strip()
            if tok:
                out.append(tok)
            cur = []
            continue
        cur.append(ch)
    tok = "".join(cur).strip()
    if tok:
        out.append(tok)
    return out


def _dot_flops(line: str, shapes: Dict[str, str]) -> float:
    # result shape = first shape on the line (the def type)
    res = _shapes_in(line.split(" dot(")[0])
    if not res:
        return 0.0
    res_elems = _prod(res[-1][1])
    m = _OPERANDS_RE.search(line[line.index(" dot(") + 4:])
    lhs_shape = None
    if m:
        ops = _split_operands(m.group(1))
        if ops:
            name = ops[0].split(" ")[-1].lstrip("%")
            if name in shapes:
                lhs_shape = _shapes_in(shapes[name])
            else:
                inline = _shapes_in(ops[0])
                lhs_shape = inline or None
    cm = _LHS_CONTRACT_RE.search(line)
    if lhs_shape and cm is not None:
        dims = lhs_shape[-1][1]
        idx = [int(i) for i in cm.group(1).split(",") if i]
        k = _prod([dims[i] for i in idx if i < len(dims)])
    else:
        k = 1
    return 2.0 * res_elems * k


def _collective_moved(kind: str, line: str) -> float:
    r = _shape_bytes(line.split(f" {kind}")[0])
    if r == 0:
        return 0.0
    n = _group_size(line)
    if kind == "all-gather":
        return r * (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * r * (n - 1) / n
    if kind == "reduce-scatter":
        return r * (n - 1)
    if kind == "all-to-all":
        return r * (n - 1) / n
    return float(r)


def analyze_hlo(text: str) -> Dict[str, float]:
    """Full call-graph cost walk. Returns per-device totals."""
    comps_lines = _split_computations(text)
    comps: Dict[str, _Comp] = {}
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(1)
    # first pass: root op kind of each computation (for in-place detection)
    comp_root_op: Dict[str, str] = {}
    for name, lines in comps_lines.items():
        for ln in lines:
            if "ROOT" in ln:
                dm = _DEF_RE.match(ln)
                if dm:
                    comp_root_op[name] = dm.group(3)

    def _operand_names_of(line: str, op: str) -> List[str]:
        i = line.find(f" {op}(")
        if i < 0:
            return []
        m = _OPERANDS_RE.search(line[i + len(op) + 1:])
        if not m:
            return []
        return [t.split(" ")[-1].lstrip("%")
                for t in _split_operands(m.group(1))]

    # true update-slice bytes of dus-rooted computations (a dus FUSION's
    # own operands include captured full buffers — look inside instead)
    dus_update_bytes: Dict[str, float] = {}
    for name, lines in comps_lines.items():
        if comp_root_op.get(name) != "dynamic-update-slice":
            continue
        sym_local = {}
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if dm:
                sym_local[dm.group(1)] = dm.group(2)
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if dm and dm.group(3) == "dynamic-update-slice":
                ops_ = _operand_names_of(ln, "dynamic-update-slice")[1:]
                dus_update_bytes[name] = sum(
                    _shape_bytes(sym_local.get(n, "")) for n in ops_)

    def _operand_names(line: str, op: str) -> List[str]:
        i = line.find(f" {op}(")
        if i < 0:
            return []
        m = _OPERANDS_RE.search(line[i + len(op) + 1:])
        if not m:
            return []
        return [t.split(" ")[-1].lstrip("%")
                for t in _split_operands(m.group(1))]

    for name, lines in comps_lines.items():
        c = _Comp(name)
        # symbol table: op name -> its def type (for operand shape lookup)
        sym: Dict[str, str] = {}
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if dm:
                sym[dm.group(1)] = dm.group(2)

        def _operand_bytes(ln, op, skip_first=False):
            names = _operand_names(ln, op)
            if skip_first:
                names = names[1:]
            return sum(_shape_bytes(sym.get(n, "")) for n in names)

        def _acct(label, b):
            c.bytes += b
            c.by_op[label] = c.by_op.get(label, 0.0) + b

        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            opname, typetxt, op = dm.group(1), dm.group(2), dm.group(3)
            in_place = op == "dynamic-update-slice"
            dus_fusion_bytes = None
            # dtype conversions are XLA:CPU float-normalization artifacts:
            # the CPU backend carries bf16 loop buffers as f32 with full
            # converts every iteration.  On the TPU target buffers stay
            # bf16 and converts fuse — count zero bytes.
            elementwise_wrapper = op == "convert"
            if op == "fusion":
                fm = _CALLS_RE.search(ln)
                root = comp_root_op.get(fm.group(1), "") if fm else ""
                if root == "dynamic-update-slice":
                    in_place = True
                    dus_fusion_bytes = dus_update_bytes.get(
                        fm.group(1), 0.0)
                if root == "convert":
                    elementwise_wrapper = True
                # XLA:CPU wraps single elementwise ops in kLoop fusions
                # ("wrapped_*"); on the TPU target these fuse into their
                # producers/consumers and touch no HBM.
                if fm and fm.group(1).startswith("wrapped_") and \
                        root not in ("dot", "reduce", "scatter", "gather",
                                     "sort"):
                    elementwise_wrapper = True
            if op == "dot":
                c.flops += _dot_flops(ln, sym)
                # result write + both operand reads (weight reads matter)
                _acct("dot", _shape_bytes(typetxt) + _operand_bytes(ln, op))
            elif op in COLLECTIVE_KINDS or any(
                    op == k + s for k in COLLECTIVE_KINDS
                    for s in ("-start",)):
                kind = op.replace("-start", "")
                if kind in COLLECTIVE_KINDS:
                    moved = _collective_moved(kind, ln)
                    c.coll[kind] = c.coll.get(kind, 0.0) + moved
                    c.coll["count"] = c.coll.get("count", 0.0) + 1
                _acct(kind, 2.0 * _shape_bytes(typetxt))
            elif op in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "after-all", "while",
                        "conditional", "call", "custom-call"):
                pass
            elif in_place:
                # in-place update: traffic = 2 x update slice, not the
                # full carried buffer (scan-backward residual stacking);
                # for dus-rooted fusions the true slice size comes from
                # inside the callee (the fusion op's operands include
                # captured full buffers)
                if dus_fusion_bytes is not None:
                    _acct("dus", 2.0 * dus_fusion_bytes)
                else:
                    _acct("dus",
                          2.0 * _operand_bytes(ln, op, skip_first=True))
            elif elementwise_wrapper:
                pass
            elif op in ("reduce", "reduce-window"):
                _acct("reduce", _shape_bytes(typetxt) + _operand_bytes(ln, op))
            else:
                # write + one read per unique buffer (operands were already
                # counted as their producers' results)
                _acct(op, 2.0 * _shape_bytes(typetxt))
            if op == "fusion":
                fm = _CALLS_RE.search(ln)
                if fm:
                    c.fusion_callees.append(fm.group(1))
                    c.edges.append((fm.group(1), 1.0))
            elif op == "while":
                trip = 1.0
                tm = _TRIP_RE.search(ln)
                if tm:
                    trip = float(tm.group(1))
                bm = _BODY_RE.search(ln)
                if bm:
                    c.edges.append((bm.group(1), trip))
                cm = _COND_RE.search(ln)
                if cm:
                    c.edges.append((cm.group(1), trip))
            elif op in ("call", "custom-call", "reduce", "sort", "scatter",
                        "select-and-scatter", "map", "conditional"):
                tm = _TO_APPLY_RE.search(ln)
                if tm:
                    c.edges.append((tm.group(1), 1.0))
                bm = _BRANCHES_RE.search(ln)
                if bm:
                    for b in bm.group(1).split(","):
                        c.edges.append((b.strip().lstrip("%"), 1.0))
        comps[name] = c

    fusion_internal = {f for c in comps.values() for f in c.fusion_callees}
    memo: Dict[str, Tuple[float, float, Dict[str, float],
                          Dict[str, float]]] = {}

    def total(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, {}, {})
        c = comps[name]
        f, b = c.flops, c.bytes
        by = dict(c.by_op)
        if name in fusion_internal:
            b = 0.0        # fusion internals don't touch HBM
            by = {}
        coll = dict(c.coll)
        for callee, mult in c.edges:
            cf, cb, cc, cby = total(callee, stack + (name,))
            f += mult * cf
            b += mult * cb
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            for k, v in cby.items():
                by[k] = by.get(k, 0.0) + mult * v
        memo[name] = (f, b, coll, by)
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective": 0.0}
    f, b, coll, by = total(entry)
    out = {"flops": f, "bytes": b,
           "collective": sum(v for k, v in coll.items() if k != "count")}
    for k, v in coll.items():
        out[f"coll_{k}"] = v
    for k, v in by.items():
        out[f"bytes_{k}"] = v
    return out


# --- legacy helpers (kept for tests / simple use) ---------------------------

def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip collective bytes via the full call-graph walk."""
    res = analyze_hlo(hlo_text)
    out = {k[len("coll_"):]: v for k, v in res.items()
           if k.startswith("coll_")}
    out["total"] = res.get("collective", 0.0)
    return out


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}", hlo_text))
