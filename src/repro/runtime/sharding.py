"""Logical-axis sharding rules.

A :class:`Rules` object maps *logical* axis names (``embed``, ``heads``,
``batch`` ...) to physical mesh axes, with divisibility-aware fallbacks.
Strategy providers (``repro.core.providers``) are essentially factories of
``Rules`` — the "compiler output" of ComParX is a set of rules per segment.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Candidate = Union[None, str, Tuple[str, ...]]


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: the replication-check kwarg
    is ``check_vma`` from jax 0.6 and ``check_rep`` before (where the
    function lives in ``jax.experimental.shard_map``)."""
    try:
        from jax import shard_map
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=check)
    except ImportError:
        from jax.experimental.shard_map import shard_map
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=check)


def _as_candidates(v) -> List[Candidate]:
    """Normalize a mapping value into an ordered candidate list."""
    if isinstance(v, list):
        return v + [None] if v and v[-1] is not None else (v or [None])
    return [v, None] if v is not None else [None]


class Rules:
    """logical axis -> mesh axes resolution with divisibility fallback."""

    def __init__(self, mapping: Dict[str, object],
                 mesh: Optional[Mesh] = None):
        self.mapping = {k: _as_candidates(v) for k, v in (mapping or {}).items()}
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) \
            if mesh is not None else {}

    # ------------------------------------------------------------------
    def _resolve_one(self, name: Optional[str], dim: int,
                     used: set) -> Optional[Tuple[str, ...]]:
        if name is None:
            return None
        for cand in self.mapping.get(name, [None]):
            if cand is None:
                return None
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            # keep only axes that exist in this mesh and are unused
            axes = tuple(a for a in axes
                         if a in self.axis_sizes and a not in used)
            if not axes:
                continue
            size = 1
            for a in axes:
                size *= self.axis_sizes[a]
            if dim % size == 0:
                used.update(axes)
                return axes
        return None

    def pspec(self, logical_axes: Sequence[Optional[str]],
              shape: Sequence[int]) -> PartitionSpec:
        used: set = set()
        parts = []
        for name, dim in zip(logical_axes, shape):
            axes = self._resolve_one(name, dim, used)
            if axes is None:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        # trim trailing Nones for tidiness
        while parts and parts[-1] is None:
            parts.pop()
        return PartitionSpec(*parts)

    def sharding(self, logical_axes, shape) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(logical_axes, shape))

    def constrain(self, x, logical_axes):
        """with_sharding_constraint by logical axes (no-op without a mesh)."""
        if self.mesh is None:
            return x
        s = self.sharding(logical_axes, x.shape)
        return jax.lax.with_sharding_constraint(x, s)

    # ------------------------------------------------------------------
    @classmethod
    def null(cls) -> "Rules":
        return cls({}, None)

    def merged(self, extra: Dict[str, object]) -> "Rules":
        m = dict(self.mapping)
        m.update({k: _as_candidates(v) for k, v in extra.items()})
        r = Rules.__new__(Rules)
        r.mapping, r.mesh, r.axis_sizes = m, self.mesh, self.axis_sizes
        return r

    def __repr__(self):
        return f"Rules({ {k: v for k, v in self.mapping.items()} })"


def batch_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    """The data-parallel axes present in a mesh (pod first for DCN)."""
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
