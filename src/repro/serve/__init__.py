"""Serving subsystem: the side of ComParX that *consumes* fused plans.

``repro.serve.step`` builds the prefill/decode step functions under a
plan; ``repro.serve.registry`` persists fused plans keyed by deployment
context (the PlanRegistry ``ComParTuner`` registers into after fusion);
``repro.serve.engine`` is the continuous-batching decode engine that
serves overlapping requests from one fixed-capacity batched program.
See docs/serving.md.
"""
from repro.serve.engine import (  # noqa: F401
    Completion, Request, ServeEngine, ServeStats,
)
from repro.serve.registry import (  # noqa: F401
    PlanRegistry, RegistryEntry, serving_shape,
)
from repro.serve.step import (  # noqa: F401
    make_decode_step, make_prefill, make_prefill_cache,
)
