"""Continuous-batching decode engine: overlapping requests, one program.

The engine serves requests from a fixed-capacity batched decode program
(slot = batch row).  Scheduling is iteration-level: every engine step
runs ONE batched ``decode_step`` with a *per-slot position vector*, new
requests are admitted into free slots between steps, and a slot is
recycled the moment its request finishes (EOS or max-tokens) — no
request waits for a batch-mate to drain.

Prefill and decode are two plan segments.  Admission prefills the
request alone (``make_prefill_cache``: a scan of the plan's decode step
over the prompt, one compiled program per prompt length) and splices the
filled cache rows into the batch at the slot; decode is the plan's
``make_decode_step`` program jitted once for the full capacity.

**Byte-identity contract.**  Row ``b`` of every batched XLA op here is a
function of row ``b``'s inputs alone (the vector-pos attention path is
built per-row on purpose), and is invariant to which row index the
request lands in.  Therefore the token stream of a request served in a
full continuously-batched run is byte-identical to the same request
served alone — and ``run(requests, max_active=1)`` *is* the sequential
one-request-at-a-time baseline, on the very same compiled program.
Tested in tests/test_serve.py.  The contract holds for dense archs; MoE
routing mixes rows across the batch (capacity/dispatch are global), so
the engine warns on MoE configs.
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.plan import Plan
from repro.models.blocks import block_cache_spec
from repro.models.model import init_cache, model_specs
from repro.models.params import init_params
from repro.serve.step import make_decode_step, make_prefill_cache

log = logging.getLogger("repro.serve")


@dataclass(frozen=True)
class Request:
    """One generation request."""
    rid: str
    prompt: Tuple[int, ...]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "prompt",
                           tuple(int(t) for t in self.prompt))
        if not self.prompt:
            raise ValueError(f"request {self.rid!r}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid!r}: max_new_tokens "
                             f"{self.max_new_tokens} < 1")


@dataclass
class Completion:
    """A finished request's stream and bookkeeping."""
    rid: str
    prompt_len: int
    tokens: List[int]               # generated tokens, prompt excluded
    finish_reason: str              # "eos" | "length"
    slot: int
    admitted_step: int              # engine step at admission
    done_step: int


@dataclass
class ServeStats:
    """Counters of one ``run()``."""
    capacity: int = 0
    n_admitted: int = 0
    n_completed: int = 0
    n_steps: int = 0                # batched decode steps
    n_prefills: int = 0
    n_prefill_tokens: int = 0
    n_tokens: int = 0               # generated tokens
    occupancy_sum: float = 0.0      # sum over steps of active/capacity
    peak_active: int = 0
    elapsed_s: float = 0.0

    @property
    def occupancy(self) -> float:
        """Mean slot occupancy over the batched decode steps."""
        return self.occupancy_sum / self.n_steps if self.n_steps else 0.0

    @property
    def tok_s(self) -> float:
        return self.n_tokens / self.elapsed_s if self.elapsed_s else 0.0

    def summary(self) -> str:
        return (f"capacity={self.capacity} admitted={self.n_admitted} "
                f"completed={self.n_completed} steps={self.n_steps} "
                f"prefills={self.n_prefills} "
                f"prefill_tokens={self.n_prefill_tokens} "
                f"tokens={self.n_tokens} occupancy={self.occupancy:.2f} "
                f"peak_active={self.peak_active} "
                f"elapsed={self.elapsed_s:.2f}s tok_s={self.tok_s:.1f}")


@dataclass
class _Slot:
    req: Request
    generated: List[int]
    admitted_step: int


def cache_batch_axes(cfg: ArchConfig):
    """Per-leaf slot-axis index of the decode cache pytree.

    Unstacked groups carry the batch on axis 0; scan-stacked groups
    (``repeats > 1``) carry layers on axis 0 and the batch on axis 1.
    """
    axes = {}
    for gi, group in enumerate(cfg.stack_plan()):
        ax = 1 if group.repeats > 1 else 0
        g = {}
        for j, kind in enumerate(group.pattern):
            cs = block_cache_spec(kind, cfg, 1, 1)
            g[f"b{j}"] = jax.tree.map(lambda _: ax, cs)
        axes[f"g{gi}"] = g
    return axes


def _put_row(caches, filled, axes, s: int):
    """Splice a B=1 cache pytree into slot ``s`` of the batch pytree."""
    def put(c, f, ax):
        idx = (slice(None),) * ax + (s,)
        return c.at[idx].set(f[(slice(None),) * ax + (0,)])
    return jax.tree.map(put, caches, filled, axes)


class ServeEngine:
    """Fixed-capacity continuous batching over one compiled decode step.

    ``capacity`` is the slot count (the compiled batch), ``cache_len``
    the per-slot sequence budget: every request must satisfy
    ``len(prompt) + max_new_tokens <= cache_len`` (windowed/recurrent
    archs ring-wrap and are exempt).  Decoding is greedy.
    """

    def __init__(self, cfg: ArchConfig, plan: Plan, *, capacity: int = 4,
                 cache_len: int = 64, mesh=None, params=None, seed: int = 0,
                 interpret: bool = True):
        if cfg.is_moe:
            log.warning(
                "%s is MoE: expert routing mixes rows across the batch, "
                "so the batched-equals-sequential byte-identity contract "
                "does not hold (streams may differ by routing pressure)",
                cfg.name)
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        self.capacity, self.cache_len = int(capacity), int(cache_len)
        step_fn, _ = make_decode_step(cfg, mesh, plan, interpret=interpret)
        self._step = jax.jit(step_fn, donate_argnums=(1,))
        # one jit object; retraces per distinct prompt length
        self._prefill = jax.jit(
            make_prefill_cache(cfg, mesh, plan, interpret=interpret),
            donate_argnums=(1,))
        self.params = params if params is not None else init_params(
            model_specs(cfg), jax.random.key(seed))
        self._axes = cache_batch_axes(cfg)
        self.stats = ServeStats(capacity=self.capacity)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence, *,
            max_active: Optional[int] = None) -> Dict[str, Completion]:
        """Serve every request to completion; returns rid -> Completion.

        ``max_active`` throttles admission below the slot capacity;
        ``max_active=1`` is the sequential one-request-at-a-time
        baseline on the same compiled program.
        """
        reqs = [r if isinstance(r, Request) else Request(**r)
                for r in requests]
        rids = [r.rid for r in reqs]
        if len(set(rids)) != len(rids):
            raise ValueError(f"duplicate request ids: {rids}")
        if not self.cfg.window_size:
            for r in reqs:
                need = len(r.prompt) + r.max_new_tokens
                if need > self.cache_len:
                    raise ValueError(
                        f"request {r.rid!r} needs {need} cache slots "
                        f"(prompt {len(r.prompt)} + {r.max_new_tokens} "
                        f"new) > cache_len={self.cache_len}")
        cap = self.capacity if max_active is None \
            else max(1, min(int(max_active), self.capacity))
        B = self.capacity
        queue = deque(reqs)
        slots: List[Optional[_Slot]] = [None] * B
        caches = init_cache(self.cfg, B, self.cache_len)
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        stats = self.stats = ServeStats(capacity=B)
        done: Dict[str, Completion] = {}
        step_i = 0
        t0 = time.perf_counter()
        while queue or any(s is not None for s in slots):
            # admission: fill free slots up to the active cap
            active = sum(s is not None for s in slots)
            for s in range(B):
                if not queue or active >= cap:
                    break
                if slots[s] is not None:
                    continue
                req = queue.popleft()
                caches, first = self._admit(caches, s, req, stats)
                slots[s] = _Slot(req, [first], step_i)
                tokens[s] = first
                pos[s] = len(req.prompt)
                active += 1
                # a 1-token request (or instant EOS) never enters the
                # batched step; its slot frees immediately
                if self._finish_if_done(slots, s, tokens, pos, done,
                                        stats, step_i):
                    active -= 1
            if not any(s is not None for s in slots):
                continue
            # one batched decode step, per-slot positions
            nxt, _, caches = self._step(self.params, caches,
                                        jnp.asarray(tokens),
                                        jnp.asarray(pos))
            step_i += 1
            n_act = sum(s is not None for s in slots)
            stats.n_steps += 1
            stats.occupancy_sum += n_act / B
            stats.peak_active = max(stats.peak_active, n_act)
            nxt_np = np.asarray(nxt)
            for s in range(B):
                sl = slots[s]
                if sl is None:
                    continue
                tok = int(nxt_np[s])
                sl.generated.append(tok)
                stats.n_tokens += 1
                tokens[s] = tok
                pos[s] += 1
                self._finish_if_done(slots, s, tokens, pos, done, stats,
                                     step_i)
        stats.elapsed_s = time.perf_counter() - t0
        return done

    # ------------------------------------------------------------------
    def _admit(self, caches, s: int, req: Request, stats: ServeStats):
        """Prefill ``req`` alone (B=1, fresh zero cache) and splice the
        filled rows into slot ``s``.  The fresh cache also resets any
        state the previous occupant left (ring buffers, recurrent h)."""
        prompt = jnp.asarray(
            np.asarray(req.prompt, np.int32)[None, :])
        fresh = init_cache(self.cfg, 1, self.cache_len)
        first, _, filled = self._prefill(self.params, fresh, prompt)
        caches = _put_row(caches, filled, self._axes, s)
        stats.n_admitted += 1
        stats.n_prefills += 1
        stats.n_prefill_tokens += len(req.prompt)
        stats.n_tokens += 1                   # the prefill's first token
        return caches, int(np.asarray(first)[0])

    @staticmethod
    def _finish_if_done(slots, s: int, tokens, pos, done, stats,
                        step_i: int) -> bool:
        sl = slots[s]
        req, tok = sl.req, sl.generated[-1]
        if req.eos_id is not None and tok == req.eos_id:
            reason = "eos"
        elif len(sl.generated) >= req.max_new_tokens:
            reason = "length"
        else:
            return False
        done[req.rid] = Completion(
            req.rid, len(req.prompt), list(sl.generated), reason, s,
            sl.admitted_step, step_i)
        slots[s] = None
        tokens[s] = 0
        pos[s] = 0
        stats.n_completed += 1
        return True
