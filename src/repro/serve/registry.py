"""PlanRegistry: fused plans persisted by deployment context.

The sweep engine answers "which plan is best for (arch, topology,
traffic shape)?"; the registry makes the answer durable.  Plans live in
the ``plan_registry`` WAL table beside ``score_cache``, keyed by
``(arch, shape signature, MeshSpec mid, executor cache_tag)`` — the same
content keys the scoring pipeline uses, so a plan can never be served to
an environment it was not tuned for.  ``ComParTuner(registry=...)``
registers the fused plan automatically after every sweep; the serving
CLI (``python -m repro.launch.serve --registry-db ...``) and the
:class:`~repro.serve.engine.ServeEngine` look plans up at request time.

``lookup`` resolves the exact key first and then falls back to the
*nearest traffic shape* of the same (arch, kind, mesh[, cache_tag]):
closest in log2 space over (seq_len, batch), deterministic tie-break.
A mesh mismatch never falls back — a plan fused for one topology is not
a plan for another.

The module is also a CLI that runs a small sweep and registers the
winner (the sweep->register half of the CI e2e)::

    python -m repro.serve.registry --db /tmp/registry.db \
        --arch stablelm-3b --smoke --batch 4 --cache-len 64
    python -m repro.serve.registry --db /tmp/registry.db --list
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.backends.scheduler import shape_key
from repro.core.db import SweepDB
from repro.core.meshspec import MeshSpec, as_mesh_point
from repro.core.plan import Plan


def serving_shape(batch: int, cache_len: int) -> ShapeConfig:
    """The ShapeConfig of a serving deployment: ``decode`` kind with the
    cache length as the sequence budget and the slot capacity as the
    global batch.  This is what a serving CLI's ``--batch``/
    ``--cache-len`` map to — and therefore the registry lookup key."""
    return ShapeConfig(f"serve_{cache_len}x{batch}", int(cache_len),
                       int(batch), "decode")


def _mesh_mid(mesh) -> str:
    """Content key of any mesh-ish value (None / MeshSpec / live Mesh /
    dict shorthand) — ``"local"`` for the meshless point."""
    if mesh is None:
        return "local"
    return as_mesh_point(mesh).mid


@dataclass
class RegistryEntry:
    """One registered plan, decoded."""
    arch: str
    shape: str                      # shape_key signature, kind:SxB
    kind: str
    seq_len: int
    batch: int
    mesh_mid: str                   # 'local' = no mesh
    cache_tag: str
    plan: Plan
    total_s: Optional[float]
    report: Dict = field(default_factory=dict)
    created: float = 0.0
    #: set by lookup(): False when served via the nearest-shape fallback
    exact: bool = True

    def describe(self) -> str:
        t = f" total={self.total_s:.3e}s" if self.total_s is not None \
            else ""
        return (f"{self.arch} {self.shape} mesh={self.mesh_mid} "
                f"tag={self.cache_tag or '-'}{t}")


class PlanRegistry:
    """Persisted fused plans keyed by ``(arch, shape, mesh, cache_tag)``.

    ``db`` is a :class:`SweepDB` or a path (the registry table lives in
    the same file as the score cache, so one DB serves both sides).
    """

    def __init__(self, db: Union[SweepDB, str]):
        self.db = db if isinstance(db, SweepDB) else SweepDB(db)

    # ------------------------------------------------------------------
    def register(self, cfg: ArchConfig, shape: ShapeConfig, plan: Plan,
                 report=None, *, mesh=None,
                 cache_tag: str = "") -> RegistryEntry:
        """Persist ``plan`` under its deployment key.  ``mesh`` defaults
        to the plan's own chosen mesh (``fuse_joint``'s argmin) — pass
        the tuner's fixed mesh for unswept sweeps.  ``report`` is a
        SweepReport (its summary is stored) or a JSON-able dict."""
        if mesh is None:
            mesh = plan.mesh
        rep: Dict = {}
        if report is not None:
            rep = report if isinstance(report, dict) \
                else {"summary": report.summary()}
        total = plan.meta.get("predicted_total_s")
        row = {"arch": cfg.name, "shape": shape_key(shape),
               "kind": shape.kind, "seq_len": shape.seq_len,
               "batch": shape.global_batch, "mesh": _mesh_mid(mesh),
               "cache_tag": cache_tag,
               "plan": json.dumps(plan.to_json(), sort_keys=True),
               "total_s": float(total) if total is not None else None,
               "report": json.dumps(rep, sort_keys=True, default=str)}
        self.db.plan_put(row)
        return self._entry(self.db.plan_get(
            row["arch"], row["shape"], row["mesh"], row["cache_tag"]))

    # ------------------------------------------------------------------
    def lookup(self, cfg: ArchConfig, shape: ShapeConfig, mesh=None, *,
               cache_tag: Optional[str] = None,
               nearest: bool = True) -> Optional[RegistryEntry]:
        """Resolve the plan for ``(cfg, shape, mesh)``.

        Exact key first; then — unless ``nearest=False`` — the closest
        registered traffic shape of the same (arch, kind, mesh[, tag]):
        minimal ``|log2 seq ratio| + |log2 batch ratio|``, ties broken
        on the (shape, cache_tag) sort order so repeated lookups always
        return the same row.  ``cache_tag=None`` matches any tag.  A
        mesh mismatch is a MISS, never a fallback."""
        sk, mid = shape_key(shape), _mesh_mid(mesh)
        if cache_tag is not None:
            row = self.db.plan_get(cfg.name, sk, mid, cache_tag)
            rows = [row] if row else []
        else:
            rows = [r for r in self.db.plan_query(arch=cfg.name, mesh=mid)
                    if r["shape"] == sk]
        if rows:
            return self._entry(rows[0], exact=True)
        if not nearest:
            return None
        cands = self.db.plan_query(arch=cfg.name, kind=shape.kind,
                                   mesh=mid, cache_tag=cache_tag)
        if not cands:
            return None

        def dist(r):
            return (abs(math.log2(max(shape.seq_len, 1))
                        - math.log2(max(r["seq_len"], 1)))
                    + abs(math.log2(max(shape.global_batch, 1))
                          - math.log2(max(r["batch"], 1))))
        best = min(cands, key=lambda r: (dist(r), r["shape"],
                                         r["cache_tag"]))
        return self._entry(best, exact=False)

    def entries(self, arch: Optional[str] = None) -> List[RegistryEntry]:
        return [self._entry(r) for r in self.db.plan_query(arch=arch)]

    # ------------------------------------------------------------------
    @staticmethod
    def _entry(row: Dict, exact: bool = True) -> RegistryEntry:
        try:
            rep = json.loads(row["report"]) if row["report"] else {}
        except ValueError:
            rep = {}
        return RegistryEntry(
            arch=row["arch"], shape=row["shape"], kind=row["kind"],
            seq_len=int(row["seq_len"]), batch=int(row["batch"]),
            mesh_mid=row["mesh"], cache_tag=row["cache_tag"],
            plan=Plan.from_json(json.loads(row["plan"])),
            total_s=row["total_s"], report=rep,
            created=float(row["created"] or 0.0), exact=exact)


# --- CLI: sweep a serving shape and register the winner ---------------------

def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.registry",
        description="Sweep a serving shape and register the fused plan "
                    "(or --list the registry)")
    ap.add_argument("--db", required=True, help="registry/score-cache DB")
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="serving slot capacity (shape global_batch)")
    ap.add_argument("--cache-len", type=int, default=64,
                    help="decode cache length (shape seq_len)")
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "sequential", "process"))
    ap.add_argument("--list", action="store_true",
                    help="print registered plans and exit")
    args = ap.parse_args(argv)

    db = SweepDB(args.db)
    reg = PlanRegistry(db)
    if args.list:
        rows = reg.entries()
        for e in rows:
            print(e.describe())
        print(f"{len(rows)} registered plan(s)")
        return 0

    from repro.configs import get_arch
    from repro.core.tuner import ComParTuner
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = serving_shape(args.batch, args.cache_len)
    tuner = ComParTuner(cfg, shape, db=db,
                        project=f"serve-{cfg.name}-{shape.name}",
                        mode="continue", executor="dryrun", registry=reg)
    with tuner:
        plan, rep = tuner.sweep(
            providers=("tensor_par", "fsdp"),
            clause_space={"remat": ("none",), "kernel": ("xla",),
                          "cache_upcast": (True, False)},
            max_flags=0, backend=args.backend, prune=True)
    print(rep.summary())
    entry = reg.lookup(cfg, shape, cache_tag=tuner.executor.cache_tag)
    assert entry is not None and entry.exact
    print(f"registered: {entry.describe()}")
    print(f"plan:\n{entry.plan.describe()}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
