"""Serve-step factories: prefill and decode under a ComParX plan.

Decode state sharding follows each segment's provider rules; KV caches of
low-kv-head archs (granite kv=8, chatglm/starcoder kv=2 on a 16-way model
axis) are sharded along the *sequence* dim with LSE-combining attention —
the XLA path expresses this purely with sharding constraints.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.plan import Plan, build_contexts
from repro.models.model import (SEG_EMBED, SEG_HEAD, cache_specs,
                                decode_step, forward)
from repro.models.rglru import rglru_dims  # noqa: F401  (docs reference)


def cache_axes(cfg: ArchConfig):
    """Logical axes mirroring ``models.model.cache_specs`` structure."""
    def for_kind(kind: str):
        if kind in ("attn", "attn_moe"):
            a = ("batch", "kv_seq", "kv_heads", None)
            return {"k": a, "v": a}
        if kind == "rec":
            return {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
        if kind == "mlstm":
            return {"C": ("batch", "heads", None, None),
                    "n": ("batch", "heads", None),
                    "m": ("batch", "heads"),
                    "conv": ("batch", None, "rnn")}
        if kind == "slstm":
            return {"h": ("batch", "heads", None),
                    "c": ("batch", "heads", None),
                    "n": ("batch", "heads", None),
                    "m": ("batch", "heads", None),
                    "conv": ("batch", None, "embed")}
        raise ValueError(kind)

    axes = {}
    for gi, group in enumerate(cfg.stack_plan()):
        g = {}
        for j, kind in enumerate(group.pattern):
            ax = for_kind(kind)
            if group.repeats > 1:
                ax = jax.tree.map(
                    lambda a: ("layers",) + tuple(a), ax,
                    is_leaf=lambda x: isinstance(x, tuple))
            g[f"b{j}"] = ax
        axes[f"g{gi}"] = g
    return axes


def cache_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh, plan: Plan):
    ctxs = build_contexts(cfg, mesh, plan)
    axes = cache_axes(cfg)
    specs = cache_specs(cfg, shape.global_batch, shape.seq_len)

    out = {}
    for seg, seg_axes in axes.items():
        rules = ctxs[seg].rules
        out[seg] = jax.tree.map(
            lambda a, s: (NamedSharding(mesh, rules.pspec(a, s.shape))
                          if mesh is not None else rules.pspec(a, s.shape)),
            seg_axes, specs[seg],
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    return out


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    B = shape.global_batch
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.dtype("int32")),
            "pos": jax.ShapeDtypeStruct((), jnp.dtype("int32"))}


def make_decode_step(cfg: ArchConfig, mesh, plan: Plan, *,
                     interpret: bool = True, greedy: bool = True):
    """Returns (serve_step, shardings). serve_step:
    (params, caches, tokens, pos) -> (next_tokens, logits, new_caches)."""
    ctxs = build_contexts(cfg, mesh, plan, interpret=interpret)

    def serve_step(params, caches, tokens, pos):
        logits, new_caches = decode_step(params, caches, tokens, pos,
                                         cfg, ctxs)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, new_caches

    from repro.train.step import param_shardings
    shardings = {"params": param_shardings(cfg, mesh, plan)}
    return serve_step, shardings


def make_prefill(cfg: ArchConfig, mesh, plan: Plan, *,
                 interpret: bool = True):
    """Full-sequence forward (prefill compute shape). Returns logits."""
    ctxs = build_contexts(cfg, mesh, plan, interpret=interpret)

    def prefill(params, batch):
        logits, _ = forward(params, batch, cfg, ctxs)
        return logits

    from repro.train.step import param_shardings
    return prefill, {"params": param_shardings(cfg, mesh, plan)}


def make_prefill_cache(cfg: ArchConfig, mesh, plan: Plan, *,
                       interpret: bool = True):
    """The serving engine's prefill segment: consume a prompt into a
    decode cache.

    :func:`make_prefill` computes full-sequence prompt logits but
    produces no KV/recurrent state, so request admission scans the
    plan's decode step across the prompt positions instead — one
    program per prompt length whose last-position logits match the
    full-sequence forward's (cross-validated in tests/test_serve.py)
    and whose output caches are exactly the state a token-by-token
    decode loop would leave behind.

    Returns ``prefill(params, caches, prompt) -> (first_tokens (B,),
    last_logits (B,V) f32, new_caches)`` where ``prompt`` is (B, P)
    int32 and ``caches`` a fresh ``init_cache`` pytree.
    """
    ctxs = build_contexts(cfg, mesh, plan, interpret=interpret)

    def prefill(params, caches, prompt):
        P = prompt.shape[1]

        def body(caches, i):
            tok = jax.lax.dynamic_index_in_dim(prompt, i, axis=1,
                                               keepdims=False)
            logits, caches = decode_step(params, caches, tok, i, cfg, ctxs)
            return caches, logits

        caches, logits = jax.lax.scan(
            body, caches, jnp.arange(P, dtype=jnp.int32))
        last = logits[-1]
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return nxt, last, caches

    return prefill


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype("int32")
    if cfg.frontend != "none":
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.dtype(cfg.dtype))}
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
