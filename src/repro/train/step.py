"""Train-step factory: applies a ComParX plan to a jitted training step.

The step is pure ``(params, opt_state, batch) -> (params, opt_state,
metrics)`` with per-segment sharding constraints, remat policies, kernel
selections, and gradient-accumulation microbatching all taken from the
plan.  ``in_shardings`` / ``out_shardings`` are derived from the same
rules, so the step is directly ``jax.jit``-able on any mesh.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.plan import Plan, build_contexts
from repro.models.loss import softmax_xent
from repro.models.model import SEG_EMBED, SEG_HEAD, forward, model_specs
from repro.models.params import abstract_params, param_pspecs
from repro.optim.adamw import (AdamWState, adamw_abstract_state, adamw_init,
                               adamw_update, cosine_lr)

AUX_LOSS_WEIGHT = 0.01


def param_shardings(cfg: ArchConfig, mesh, plan: Plan):
    """Per-segment PartitionSpec tree for params (NamedSharding if mesh)."""
    specs = model_specs(cfg)
    ctxs = build_contexts(cfg, mesh, plan)
    pspecs = {seg: param_pspecs(spec_tree, ctxs[seg].rules)
              for seg, spec_tree in specs.items()}
    if mesh is None:
        return pspecs
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def opt_shardings(cfg: ArchConfig, mesh, plan: Plan) -> AdamWState:
    ps = param_shardings(cfg, mesh, plan)
    scalar = NamedSharding(mesh, PartitionSpec()) if mesh is not None \
        else PartitionSpec()
    return AdamWState(step=scalar, m=ps, v=ps)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, object]:
    """Abstract training batch (ShapeDtypeStruct stand-ins)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype("int32")
    out: Dict[str, object] = {"targets": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.frontend != "none":
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                             jnp.dtype(cfg.dtype))
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    return out


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh, plan: Plan):
    ctxs = build_contexts(cfg, mesh, plan)
    rules = ctxs[SEG_EMBED].rules
    specs = batch_specs(cfg, shape)
    axes = {"tokens": ("batch", "seq"), "targets": ("batch", "seq"),
            "embeds": ("batch", "seq", "embed")}
    out = {}
    for k, sds in specs.items():
        ps = rules.pspec(axes[k], sds.shape)
        out[k] = NamedSharding(mesh, ps) if mesh is not None else ps
    return out


def make_train_step(cfg: ArchConfig, mesh, plan: Plan, *,
                    interpret: bool = True,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000,
                    weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (train_step_fn, shardings dict)."""
    ctxs = build_contexts(cfg, mesh, plan, interpret=interpret)
    mb = plan.knobs.microbatches

    def loss_fn(params, batch):
        logits, aux = forward(params, batch, cfg, ctxs)
        loss, metrics = softmax_xent(logits, batch["targets"])
        total = loss + AUX_LOSS_WEIGHT * aux
        metrics = dict(metrics, loss=loss, aux_loss=aux)
        return total, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state: AdamWState, batch):
        if mb > 1:
            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)

            def acc_step(carry, mbatch):
                gacc, lacc = carry
                loss, metrics, grads = grads_of(params, mbatch)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), metrics

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), metrics = jax.lax.scan(
                acc_step, (gz, jnp.zeros(())), split)
            grads = jax.tree.map(lambda g: (g / mb).astype(jnp.float32),
                                 gsum)
            metrics = jax.tree.map(lambda x: x[-1], metrics)
            loss = lsum / mb
        else:
            loss, metrics, grads = grads_of(params, batch)
        lr = cosine_lr(opt_state.step, peak_lr=peak_lr, warmup=warmup,
                       total=total_steps)
        new_params, new_state, opt_metrics = adamw_update(
            grads, opt_state, params, lr=lr,
            weight_decay=weight_decay, clip_norm=clip_norm)
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return new_params, new_state, metrics

    shardings = {
        "params": param_shardings(cfg, mesh, plan),
        "opt": opt_shardings(cfg, mesh, plan),
    }
    return train_step, shardings


def abstract_train_state(cfg: ArchConfig, plan: Plan):
    specs = model_specs(cfg)
    params = abstract_params(specs)
    opt = adamw_abstract_state(params, plan.knobs.opt_state_dtype)
    return params, opt


def init_train_state(cfg: ArchConfig, plan: Plan, key):
    from repro.models.params import init_params
    specs = model_specs(cfg)
    params = init_params(specs, key)
    opt = adamw_init(params, plan.knobs.opt_state_dtype)
    return params, opt


def jit_train_step(cfg: ArchConfig, mesh, plan: Plan, *,
                   interpret: bool = True, **kw):
    """jit the step with in/out shardings + donation per the plan knobs."""
    step, sh = make_train_step(cfg, mesh, plan, interpret=interpret, **kw)
    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1)
                       if plan.knobs.donate else ()), sh
    bs = None  # batch shardings are data-dependent; constrain inside
    jitted = jax.jit(
        step,
        in_shardings=(sh["params"], sh["opt"], bs),
        out_shardings=(sh["params"], sh["opt"], None),
        donate_argnums=(0, 1) if plan.knobs.donate else (),
    )
    return jitted, sh
