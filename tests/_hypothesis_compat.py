"""Deterministic mini-fallback for ``hypothesis`` (not installed in the
runtime container).

Implements just the surface the test suite uses — ``given`` / ``settings``
/ ``strategies.{integers,floats,lists,composite}`` — by drawing a fixed
number of seeded pseudo-random examples per test.  Property coverage is
weaker than real hypothesis (no shrinking, no edge-case bias), but the
properties still execute instead of the whole module failing to import.
"""
from __future__ import annotations

import random


class Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 16):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def lists(elements: Strategy, min_size=0, max_size=10):
        return Strategy(lambda rng: [
            elements.example(rng)
            for _ in range(rng.randint(min_size, max_size))])

    @staticmethod
    def composite(fn):
        def builder(*args, **kwargs):
            def draw_with(rng):
                return fn(lambda s: s.example(rng), *args, **kwargs)
            return Strategy(draw_with)
        return builder


strategies = _Strategies()

_DEFAULT_EXAMPLES = 20


def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*strats: Strategy):
    def deco(fn):
        def wrapper():
            # NOT functools.wraps: pytest would follow __wrapped__ to the
            # original signature and demand fixtures for the drawn args.
            n = getattr(fn, "_compat_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(0)
            for _ in range(n):
                fn(*(s.example(rng) for s in strats))
        for attr in ("__name__", "__qualname__", "__doc__", "__module__",
                     "pytestmark"):
            if hasattr(fn, attr):
                setattr(wrapper, attr, getattr(fn, attr))
        return wrapper
    return deco
