"""PlanLint: the static validity analyzer and its soundness contract.

The contract under test: every point the analyzer rejects at error
severity really does fail to compile (force-compiled here, row by row),
and on an all-valid space a strict sweep fuses a plan byte-identical to
an unlinted one — the lint is a pure accelerator, never an approximation.
Satellites ride along: the black-box validator catches a
numerics-corrupting plan, and the HLO analyzer's bytes/flops accounting
is pinned against a hand-written fixture.
"""
import dataclasses
import json

import pytest

from repro.analysis import (Diagnostic, analyze_plan, analyze_point, errors,
                            format_diagnostics, lint_schedule)
from repro.analysis.diagnostics import ERROR, WARN
from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.combinator import Combination, GlobalKnobs
from repro.core.executor import CombinationFailed, DryRunExecutor
from repro.core.meshspec import MeshSpec
from repro.core.plan import Plan, uniform_plan
from repro.core.segment import fragment
from repro.models.context import SegmentClause


@pytest.fixture(scope="module")
def cfg():
    return get_arch("stablelm-3b").smoke()


@pytest.fixture(scope="module")
def shape():
    return get_shape("train_4k").smoke()


@pytest.fixture(scope="module")
def decode_shape():
    return get_shape("decode_32k").smoke()


def _combo(provider="fsdp", flags=(), **clause):
    return Combination(provider, frozenset(flags), SegmentClause(**clause))


# --- rule units -------------------------------------------------------------

def test_valid_point_is_clean(cfg, shape):
    assert analyze_point(cfg, shape, _combo(), knobs=GlobalKnobs()) == []


def test_microbatch_rule(cfg, shape):
    diags = analyze_point(cfg, shape, _combo(),
                          knobs=GlobalKnobs(microbatches=3))
    assert [d.rule for d in diags] == ["microbatch"]
    assert diags[0].is_error
    assert diags[0].evidence["global_batch"] == shape.global_batch
    # divisible split: clean
    assert analyze_point(cfg, shape, _combo(),
                         knobs=GlobalKnobs(microbatches=2)) == []


def test_attn_tile_rule(cfg, shape):
    diags = analyze_point(cfg, shape, _combo(kernel="pallas", block_q=24,
                                             block_k=32))
    tile = [d for d in diags if d.rule == "attn-tile"]
    assert tile and all(d.is_error for d in tile)
    # the tile rule anchors to the stack segment, not embed/head
    assert {d.segment for d in tile} == {"g0"}
    assert not errors(analyze_point(
        cfg, shape, _combo(kernel="pallas", block_q=16, block_k=32)))


def test_attn_chunk_fallback_warns_on_xla(cfg, shape):
    diags = analyze_point(cfg, shape, _combo(kernel="xla", block_q=24))
    fall = [d for d in diags if d.rule == "attn-chunk-fallback"]
    assert fall and all(d.severity == WARN for d in fall)


def test_decode_tile_rule_and_shardmap_demotion(cfg, decode_shape):
    bad = _combo(kernel="pallas", block_k=24)
    diags = analyze_point(cfg, decode_shape, bad)
    tile = [d for d in diags if d.rule == "decode-tile"]
    assert tile and tile[0].is_error
    # decode_shardmap may route around the kernel (the gate is
    # data-dependent), so strict mode must not reject the point
    demoted = analyze_point(
        cfg, decode_shape, _combo(kernel="pallas", block_k=24,
                                  decode_shardmap=True))
    tile = [d for d in demoted if d.rule == "decode-tile"]
    assert tile and tile[0].severity == WARN


def test_chunk_clamp_schedule_lint(cfg, shape):
    diags = lint_schedule("mlstm_chunkwise",
                          {"kernel": "pallas", "mlstm_chunk": 24},
                          cfg, shape)
    assert [d.rule for d in diags] == ["chunk-clamp"]
    assert diags[0].severity == WARN
    assert lint_schedule("mlstm_chunkwise",
                         {"kernel": "pallas", "mlstm_chunk": 16},
                         cfg, shape) == []


def test_shard_fallback_warns_only_on_divisibility(cfg, shape):
    # model=3 divides neither heads=2 nor ffn: Rules silently replicates
    diags = analyze_point(cfg, shape, _combo("tensor_par"),
                          mesh=MeshSpec((("model", 3),)))
    fall = [d for d in diags if d.rule == "shard-fallback"]
    assert fall and all(d.severity == WARN for d in fall)
    # an axis merely absent from the mesh is structural, not a fallback
    assert not [d for d in analyze_point(cfg, shape, _combo("tensor_par"),
                                         mesh=MeshSpec((("data", 2),)))
                if d.rule == "shard-fallback"]


def test_mesh_devices_rule_is_opt_in(cfg, shape):
    # data=2 divides every mapped dim (no shard-fallback) but exceeds
    # this 1-device CPU host — only check_devices=True may reject that
    big = MeshSpec((("data", 2),))
    assert analyze_point(cfg, shape, _combo(), mesh=big) == []
    diags = analyze_point(cfg, shape, _combo(), mesh=big, check_devices=True)
    assert [d.rule for d in errors(diags)] == ["mesh-devices"]


def test_opt_state_dtype_warns_once_per_point(cfg, shape):
    diags = analyze_point(cfg, shape, _combo(),
                          knobs=GlobalKnobs(opt_state_dtype="bfloat16"))
    assert [d.rule for d in diags] == ["dtype-flow"]


def test_cache_upcast_dtype_flow(cfg, decode_shape):
    bf16 = dataclasses.replace(cfg, dtype="bfloat16")
    diags = analyze_point(bf16, decode_shape, _combo(cache_upcast=False))
    assert any(d.rule == "dtype-flow" for d in diags)
    assert not any(d.rule == "dtype-flow"
                   for d in analyze_point(bf16, decode_shape, _combo()))


def test_trace_rule_reproduces_microbatch_failure(cfg, shape):
    diags = analyze_point(cfg, shape, _combo(),
                          knobs=GlobalKnobs(microbatches=3), trace=True)
    assert any(d.rule == "trace" for d in diags)
    clean = analyze_point(cfg, shape, _combo(), knobs=GlobalKnobs(),
                          trace=True)
    assert clean == []          # valid point: trace + donation both clean


def test_diagnostic_roundtrip_and_format():
    d = Diagnostic("attn-tile", ERROR, "boom", segment="g0",
                   evidence={"seq_len": 32})
    assert Diagnostic.from_json(d.to_json()) == d
    assert "ERROR" in str(d) and "g0" in str(d)
    w = Diagnostic("chunk-clamp", WARN, "meh")
    txt = format_diagnostics([w, d])
    assert txt.index("attn-tile") < txt.index("chunk-clamp")  # errors first
    with pytest.raises(ValueError):
        Diagnostic("x", "fatal", "nope")


# --- sweep wiring + the soundness contract ---------------------------------

INVALID_SPACE = {"remat": ("none",), "kernel": ("xla", "pallas"),
                 "block_q": (16, 24), "block_k": (32,),
                 "scan_unroll": (1,), "mlstm_chunk": (16,)}
INVALID_GLOBALS = {"microbatches": (1, 3)}


def _sweep(cfg, shape, checks, project="lint", db=None, **kw):
    tuner = ComParTuner(cfg, shape, mesh=None, db=db or SweepDB(":memory:"),
                        project=project, mode="new", executor="dryrun")
    plan, rep = tuner.sweep(providers=["fsdp"], clause_space=INVALID_SPACE,
                            global_space=INVALID_GLOBALS, max_flags=0,
                            static_checks=checks, **kw)
    return tuner, plan, rep


@pytest.fixture(scope="module")
def strict_sweep(cfg, shape):
    db = SweepDB(":memory:")
    tuner, plan, rep = _sweep(cfg, shape, "strict", db=db)
    return db, tuner, plan, rep


def test_strict_rejects_and_accounts(strict_sweep):
    db, tuner, plan, rep = strict_sweep
    assert rep.n_static > 0
    assert rep.n_failed == 0          # every invalid point caught statically
    assert rep.static_rules.get("microbatch", 0) > 0
    assert rep.static_rules.get("attn-tile", 0) > 0
    s = rep.summary()
    assert f"static={rep.n_static}" in s and "microbatch:" in s


def test_static_rows_soundness_force_compile(strict_sweep, cfg, shape):
    """THE contract: force-compile every statically rejected row and
    assert each one actually fails — strict mode never drops a point the
    compiler would have accepted."""
    db, tuner, plan, rep = strict_sweep
    rows = [r for r in db.results(tuner.project) if r["status"] == "static"]
    assert len(rows) == rep.n_static > 0
    segs = {s.name: s for s in fragment(cfg)}
    ex = DryRunExecutor(None)
    seen = set()
    for r in rows:
        key = (r["segment"], r["combo"].label(),
               r["knobs"].key() if r["knobs"] else "")
        if key in seen:            # identical program: one compile suffices
            continue
        seen.add(key)
        with pytest.raises(CombinationFailed):
            ex.score_segment(cfg, shape, segs[r["segment"]], r["combo"],
                             knobs=r["knobs"])


def test_static_rows_never_enter_score_cache(strict_sweep):
    db, tuner, plan, rep = strict_sweep
    statuses = {s for (s,) in
                db.conn.execute("SELECT status FROM score_cache")}
    assert statuses <= {"done"}   # rejected points were never even scored


def test_warn_mode_accounts_but_drops_nothing(cfg, shape):
    _, plan_w, rep_w = _sweep(cfg, shape, "warn")
    assert rep_w.n_static == 0            # nothing settled as static
    assert rep_w.n_failed > 0             # invalid points still dispatched
    assert rep_w.static_rules.get("microbatch", 0) > 0   # ...but accounted


def test_strict_off_warn_fuse_identical_plans(cfg, shape, strict_sweep):
    _, _, plan_s, rep_s = strict_sweep
    _, plan_o, rep_o = _sweep(cfg, shape, "off")
    _, plan_w, _ = _sweep(cfg, shape, "warn")
    bs = json.dumps(plan_s.to_json(), sort_keys=True)
    assert bs == json.dumps(plan_o.to_json(), sort_keys=True)
    assert bs == json.dumps(plan_w.to_json(), sort_keys=True)
    # strict really did skip the dispatches the off run paid for
    assert rep_o.n_failed == rep_s.n_static


def test_bad_static_checks_value_raises(cfg, shape):
    with pytest.raises(ValueError):
        _sweep(cfg, shape, "pedantic")


def test_inapplicable_provider_rows_are_counted(cfg, shape):
    # expert_par declares itself inapplicable to dense (non-MoE) stacks:
    # those rows are dropped pre-registration and now accounted
    tuner = ComParTuner(cfg, shape, mesh=None, db=SweepDB(":memory:"),
                        project="inap", mode="new", executor="dryrun")
    plan, rep = tuner.sweep(
        providers=["fsdp", "expert_par"],
        clause_space={"kernel": ("xla",), "block_q": (16,)}, max_flags=0)
    assert rep.n_inapplicable > 0
    assert f"inapplicable={rep.n_inapplicable}" in rep.summary()


# --- plan lint --------------------------------------------------------------

def test_plan_lint_clean_and_boundary_reshard(cfg, shape):
    assert uniform_plan(cfg, "fsdp").lint(cfg, shape) == []
    # a mixed plan whose middle segment shards the residual seq dim
    # forces an unpriced reshard at both boundaries
    mesh = MeshSpec((("data", 2), ("model", 2)))
    plan = Plan({"embed": _combo(), "g0": _combo("tensor_par",
                                                flags=("seq_parallel",)),
                 "head": _combo()}, GlobalKnobs(), {}, mesh)
    diags = analyze_plan(cfg, shape, plan, trace=False)
    reshard = [d for d in diags if d.rule == "boundary-reshard"]
    assert len(reshard) == 2 and all(d.severity == WARN for d in reshard)
    # Viterbi-fused plans priced the boundary: exempt
    plan.meta["fusion"] = "viterbi-boundary"
    assert not [d for d in analyze_plan(cfg, shape, plan, trace=False)
                if d.rule == "boundary-reshard"]


def test_plan_lint_missing_segment_and_errors(cfg, shape):
    plan = Plan({"embed": _combo()}, GlobalKnobs(microbatches=3))
    diags = analyze_plan(cfg, shape, plan, trace=False)
    assert any(d.rule == "missing-segment" for d in diags)
    assert any(d.rule == "microbatch" and d.is_error for d in diags)
    assert diags[0].is_error          # errors sort first


# --- CLI --------------------------------------------------------------------

def test_lint_cli_plan_and_sweep(tmp_path, cfg, capsys):
    from repro.analysis.lint import main
    ppath = tmp_path / "plan.json"
    uniform_plan(cfg, "fsdp").save(str(ppath))
    assert main([str(ppath)]) == 0
    assert "plan" in capsys.readouterr().out

    spec = {"providers": {"fsdp": []},
            "clauses": {"kernel": ["pallas"], "block_q": [24],
                        "block_k": [32]},
            "globals": {"microbatches": [1]}}
    spath = tmp_path / "sweep.json"
    spath.write_text(json.dumps(spec))
    assert main([str(spath)]) == 2          # attn-tile errors gate the CI
    out = capsys.readouterr().out
    assert "attn-tile" in out and "error" in out
    assert main([str(tmp_path / "missing.json")]) == 1


def test_lint_cli_strict_gates_warnings(tmp_path, capsys):
    from repro.analysis.lint import main
    spec = {"providers": {"fsdp": []},
            "clauses": {"kernel": ["xla"], "block_q": [24]},
            "globals": {"microbatches": [1]}}
    spath = tmp_path / "sweep.json"
    spath.write_text(json.dumps(spec))
    assert main([str(spath)]) == 0          # warnings only
    assert main([str(spath), "--strict"]) == 2
    capsys.readouterr()


# --- autotuner pre-check ----------------------------------------------------

def test_autotune_rejects_invalid_schedule_statically(cfg, shape):
    from repro.kernels.autotune import _measure_one
    ex = DryRunExecutor(None)
    bad = _measure_one("flash_attention",
                       {"kernel": "pallas", "block_q": 24, "block_k": 32},
                       cfg, shape, ex)
    assert bad["status"] == "failed" and bad["error"].startswith("static:")
    assert "attn-tile" in bad["error"]
    good = _measure_one("flash_attention",
                        {"kernel": "xla", "block_q": 16, "block_k": 32},
                        cfg, shape, ex)
    assert good["status"] == "done"


# --- satellite: black-box validator -----------------------------------------

def test_validator_passes_reference_and_pallas(cfg):
    ok, msg = __import__("repro.core.validator",
                         fromlist=["validate_plan"]).validate_plan(
        cfg, uniform_plan(cfg, "fsdp"))
    assert ok, msg


def test_validator_rejects_numerics_corrupting_plan(cfg, monkeypatch):
    """A plan routed through a (deliberately broken) kernel must be
    rejected by the black-box check — the paper's user-testing-script
    rejection, exercised end to end."""
    import repro.kernels as kops
    from repro.core.validator import validate_plan
    plan = uniform_plan(cfg, "fsdp",
                        clause=SegmentClause(kernel="pallas", block_q=16,
                                             block_k=16))
    ok, msg = validate_plan(cfg, plan)
    assert ok, msg                          # sane kernel: within tolerance
    real = kops.flash_attention
    monkeypatch.setattr(kops, "flash_attention",
                        lambda *a, **k: real(*a, **k) * 1.5)
    ok, msg = validate_plan(cfg, plan)
    assert not ok and "mismatch" in msg


# --- satellite: HLO bytes-accounting regression fixture ---------------------

# A hand-written optimized-HLO module pinning the analyzer's accounting:
# a while loop with known_trip_count=3 (dot + all-reduce per iteration),
# an entry dot in the OLDER inline-typed-operand form (operand shapes on
# the line, names absent from the symbol table), a dus-rooted fusion
# (traffic = 2 x update slice, captured full buffers excluded), an
# iota-form and a list-form replica_groups collective, and converts
# (CPU float-normalization artifacts — zero bytes).
PINNED_HLO = """\
HloModule pinned_accounting

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%update.0 (param_0: f32[8,16], param_1: f32[1,16], param_2: s32[]) -> f32[8,16] {
  %param_0 = f32[8,16]{1,0} parameter(0)
  %param_1 = f32[1,16]{1,0} parameter(1)
  %param_2 = s32[] parameter(2)
  %zero = s32[] constant(0)
  ROOT %dus.1 = f32[8,16]{1,0} dynamic-update-slice(%param_0, %param_1, %param_2, %zero)
}

%wbody (warg: (f32[4,8], f32[8,8], s32[])) -> (f32[4,8], f32[8,8], s32[]) {
  %warg = (f32[4,8]{1,0}, f32[8,8]{1,0}, s32[]) parameter(0)
  %x = f32[4,8]{1,0} get-tuple-element(%warg), index=0
  %w = f32[8,8]{1,0} get-tuple-element(%warg), index=1
  %i = s32[] get-tuple-element(%warg), index=2
  %mm = f32[4,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,8]{1,0} all-reduce(%mm), replica_groups=[1,4], to_apply=%add
  %one = s32[] constant(1)
  %inext = s32[] add(%i, %one)
  ROOT %wt = (f32[4,8]{1,0}, f32[8,8]{1,0}, s32[]) tuple(%ar, %w, %inext)
}

%wcond (carg: (f32[4,8], f32[8,8], s32[])) -> pred[] {
  %carg = (f32[4,8]{1,0}, f32[8,8]{1,0}, s32[]) parameter(0)
  %iter = s32[] get-tuple-element(%carg), index=2
  %limit = s32[] constant(3)
  ROOT %lt = pred[] compare(%iter, %limit), direction=LT
}

ENTRY %main (p0: f32[4,8], p1: f32[8,8], p2: f32[8,16], p3: f32[1,16], p4: s32[]) -> f32[4,16] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,8]{1,0} parameter(1)
  %p2 = f32[8,16]{1,0} parameter(2)
  %p3 = f32[1,16]{1,0} parameter(3)
  %p4 = s32[] parameter(4)
  %c0 = s32[] constant(0)
  %init = (f32[4,8]{1,0}, f32[8,8]{1,0}, s32[]) tuple(%p0, %p1, %c0)
  %loop = (f32[4,8]{1,0}, f32[8,8]{1,0}, s32[]) while(%init), condition=%wcond, body=%wbody, backend_config={"known_trip_count":{"n":"3"}}
  %xout = f32[4,8]{1,0} get-tuple-element(%loop), index=0
  %cast = bf16[4,8]{1,0} convert(%xout)
  %recast = f32[4,8]{1,0} convert(%cast)
  %proj = f32[4,16]{1,0} dot(f32[4,8]{1,0} %lhs.inline, f32[8,16]{1,0} %p2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %cache = f32[8,16]{1,0} fusion(%p2, %p3, %p4), kind=kLoop, calls=%update.0
  %ag = f32[8,16]{1,0} all-gather(%p3), replica_groups={{0,1},{2,3}}, dimensions={0}
  ROOT %out = f32[4,16]{1,0} add(%proj, %proj)
}
"""


def test_hlo_bytes_accounting_pinned_fixture():
    from repro.runtime.hlo import analyze_hlo, collective_bytes, count_ops
    res = analyze_hlo(PINNED_HLO)
    # flops: entry inline-typed dot 2*64*8=1024 (the K comes off the
    # inline operand type, not the symbol table) + 3 x body dot 512
    assert res["flops"] == 1024 + 3 * 512
    # bytes, per the documented accounting:
    #   entry: dot 256+512 (inline lhs unresolved -> 0) + dus-fusion
    #   2*72 + all-gather 2*512 + root add 2*256; converts/params/
    #   tuple/while/gte: 0
    #   body x3: dot 128+128+256, all-reduce 2*128, add 2*4
    #   cond x3: compare 2*1
    assert res["bytes"] == (768 + 144 + 1024 + 512) + 3 * 776 + 3 * 2
    assert res["bytes_dot"] == 768 + 3 * 512
    assert res["bytes_dus"] == 144           # 2 x update slice, not 2x8x16
    # collectives: all-reduce ring 2r(n-1)/n with n=4 (iota groups),
    # all-gather r(n-1)/n with n=2 (list groups)
    assert res["coll_all-reduce"] == 3 * (2 * 128 * 3 / 4)
    assert res["coll_all-gather"] == 512 * 1 / 2
    assert res["collective"] == 576 + 256
    assert res["coll_count"] == 4
    legacy = collective_bytes(PINNED_HLO)
    assert legacy["total"] == res["collective"]
    assert count_ops(PINNED_HLO, "dot") == 2
