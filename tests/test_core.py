"""ComParX core: combinator counting (paper formula), DB modes,
fusion guarantee — with hypothesis property tests."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # container lacks hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_arch, get_shape
from repro.core.combinator import (Combination, GlobalKnobs, clause_grid,
                                   enumerate_combinations, flag_subsets,
                                   paper_combination_count)
from repro.core.cost_model import CostTerms
from repro.core.db import SweepDB
from repro.core.fusion import best_uniform, fuse
from repro.core.plan import Plan, uniform_plan
from repro.core.providers import all_providers, get_provider
from repro.core.segment import fragment
from repro.models.context import SegmentClause


# --- paper formula -----------------------------------------------------------

@given(st.lists(st.integers(0, 8), min_size=1, max_size=5),
       st.integers(0, 4), st.integers(0, 4))
def test_paper_combination_count_formula(ns, rtl, d):
    expect = sum((2 ** n - 1) * (2 ** (rtl + d) - 1) for n in ns)
    assert paper_combination_count(ns, rtl, d) == expect


@given(st.integers(0, 6))
def test_flag_subsets_cardinality(n):
    flags = [f"f{i}" for i in range(n)]
    subsets = flag_subsets(flags)
    assert len(subsets) == 2 ** n                 # incl. bare provider
    assert len(set(subsets)) == len(subsets)      # unique


def test_enumeration_count_matches_product():
    space = {"remat": ("none", "dots"), "kernel": ("xla",),
             "block_q": (256, 512), "block_k": (512,),
             "scan_unroll": (1,), "mlstm_chunk": (256,)}
    providers = ["tensor_par", "fsdp"]
    combos = enumerate_combinations(providers, space)
    n_clauses = len(clause_grid(space))
    expect = sum(2 ** len(get_provider(p).flags) for p in providers) \
        * n_clauses
    assert len(combos) == expect
    assert len({c.cid for c in combos}) == len(combos)


def test_enumeration_budget_is_deterministic():
    combos1 = enumerate_combinations(["tensor_par"], budget=5, seed=3)
    combos2 = enumerate_combinations(["tensor_par"], budget=5, seed=3)
    assert [c.cid for c in combos1] == [c.cid for c in combos2]
    assert len(combos1) == 5


def test_combination_json_roundtrip():
    c = Combination("fsdp", frozenset({"shard_both_axes"}),
                    SegmentClause(remat="dots", kernel="pallas"))
    c2 = Combination.from_json(c.to_json())
    assert c == c2 and c.cid == c2.cid


# --- DB modes ----------------------------------------------------------------

def _combo(i=0):
    return Combination("fsdp", frozenset(), SegmentClause(block_q=256 + i))


def test_db_new_mode_appends_index():
    db = SweepDB(":memory:")
    assert db.open_project("p", "new") == "p"
    assert db.open_project("p", "new") == "p_1"
    assert db.open_project("p", "new") == "p_2"


def test_db_overwrite_mode():
    db = SweepDB(":memory:")
    db.open_project("p", "new")
    db.register("p", "g0", _combo())
    db.record("p", "g0", _combo().cid, status="done", cost={"total_s": 1})
    db.open_project("p", "overwrite")
    assert db.results("p") == []


def test_db_continue_mode_preserves_results():
    db = SweepDB(":memory:")
    db.open_project("p", "new")
    db.register("p", "g0", _combo())
    db.record("p", "g0", _combo().cid, status="done",
              cost={"compute_s": 1.0})
    assert db.open_project("p", "continue") == "p"
    rows = db.results("p")
    assert len(rows) == 1 and rows[0]["status"] == "done"
    # re-register is a no-op (the resume path)
    db.register("p", "g0", _combo())
    assert db.status("p", "g0", _combo().cid) == "done"


# --- fusion guarantee (hypothesis) ------------------------------------------

@st.composite
def cost_tables(draw):
    cfg = get_arch("granite-8b").smoke()
    segs = fragment(cfg)
    n_combos = draw(st.integers(2, 5))
    combos = [Combination("fsdp", frozenset(),
                          SegmentClause(block_q=128 + i))
              for i in range(n_combos)]
    table = {}
    for s in segs:
        rows = []
        for c in combos:
            t = draw(st.floats(1e-4, 10.0, allow_nan=False))
            rows.append((c, CostTerms(compute_s=t)))
        table[s.name] = rows
    return cfg, table


@given(cost_tables())
@settings(max_examples=25, deadline=None)
def test_fusion_never_worse_than_best_uniform(cfg_table):
    """ComPar's theoretical guarantee (paper §4.1): the fused output is at
    least as good as the best single compiler."""
    cfg, table = cfg_table
    shape = get_shape("train_4k").smoke()
    plan = fuse(cfg, shape, None, table)
    _, best_total = best_uniform(cfg, table)
    assert plan.meta["predicted_total_s"] <= best_total + 1e-9


@given(cost_tables())
@settings(max_examples=10, deadline=None)
def test_viterbi_equals_argmin_without_boundaries(cfg_table):
    cfg, table = cfg_table
    shape = get_shape("train_4k").smoke()
    p1 = fuse(cfg, shape, None, table, boundary_costs=False)
    p2 = fuse(cfg, shape, None, table, boundary_costs=True)  # mesh=None -> 0
    assert abs(p1.meta["predicted_total_s"]
               - p2.meta["predicted_total_s"]) < 1e-9


def test_viterbi_fusion_matches_brute_force_with_boundary_costs(monkeypatch):
    """Exactness of the Viterbi DP beyond the degenerate mesh=None case:
    on a meshed 3-segment chain with non-trivial (deterministic,
    asymmetric) boundary costs, ``fuse(boundary_costs=True)`` must equal
    the exhaustive minimum over every combination chain."""
    import hashlib
    import itertools

    import repro.core.fusion as F

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    segs = fragment(cfg)
    assert len(segs) == 3                       # embed, g0, head
    combos = [Combination("fsdp", frozenset(),
                          SegmentClause(block_q=128 + 16 * i))
              for i in range(3)]

    def synth_cost(cid: str) -> float:
        return int(hashlib.sha1(cid.encode()).hexdigest()[:6], 16) / 0xffffff

    table = {s.name: [(c, CostTerms(compute_s=synth_cost(s.name + c.cid)))
                      for c in combos] for s in segs}

    def synth_boundary(cfg_, shape_, mesh_, a, sa, b, sb, hw=None):
        # deterministic, direction-sensitive stand-in for the resharding
        # collective a real mesh would charge
        return synth_cost(sa.name + a.cid + sb.name + b.cid)

    monkeypatch.setattr(F, "boundary_cost_s", synth_boundary)
    mesh_sentinel = object()                    # only boundary_cost_s sees it
    plan = F.fuse(cfg, shape, mesh_sentinel, table, boundary_costs=True)

    # brute force over all 3^3 chains
    best_total, best_chain = None, None
    for chain in itertools.product(range(3), repeat=len(segs)):
        total = sum(table[s.name][chain[i]][1].total_s
                    for i, s in enumerate(segs))
        for i in range(1, len(segs)):
            a, sa = table[segs[i - 1].name][chain[i - 1]][0], segs[i - 1]
            b, sb = table[segs[i].name][chain[i]][0], segs[i]
            total += synth_boundary(cfg, shape, mesh_sentinel, a, sa, b, sb)
        if best_total is None or total < best_total:
            best_total, best_chain = total, chain

    assert abs(plan.meta["predicted_total_s"] - best_total) < 1e-12
    expected = {s.name: combos[best_chain[i]] for i, s in enumerate(segs)}
    assert plan.segments == expected
    assert plan.meta["fusion"] == "viterbi-boundary"


def test_plan_json_roundtrip(tmp_path):
    cfg = get_arch("granite-8b").smoke()
    plan = uniform_plan(cfg, "hybrid2d", frozenset({"shard_vocab"}),
                        SegmentClause(remat="dots"),
                        GlobalKnobs(microbatches=2))
    path = str(tmp_path / "plan.json")
    plan.save(path)
    p2 = Plan.load(path)
    assert p2.segments == plan.segments
    assert p2.knobs == plan.knobs


def test_provider_applicability():
    cfg = get_arch("qwen3-moe-30b-a3b")
    segs = {s.name: s for s in fragment(cfg)}
    ep = all_providers()["expert_par"]
    assert ep.applicable(cfg, segs["g0"])      # MoE stack
    assert ep.applicable(cfg, segs["embed"])   # non-stack ok
    dense = get_arch("granite-8b")
    dseg = [s for s in fragment(dense) if s.kind == "stack"][0]
    assert not ep.applicable(dense, dseg)      # dense stack: NO
