"""Chaos-hardened scoring: the fault-injection acceptance suite.

The invariant under test, end to end: under ANY deterministic
``FaultPlan`` schedule (submit-time outages, corrupt/truncated replies,
5xx storms, a server restart mid-batch, worker kills, a crashing
recorder flush) the sweep terminates without hanging, the fused plan is
byte-identical to the fault-free sequential baseline whenever all jobs
eventually score, transients are retried in-sweep up to the budget, and
no injected failure ever writes a ``score_cache`` row.
"""
import json
import socket
import threading
import time

import pytest

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.backends import (FallbackBackend, JobGroup, JobSpec, Recorder,
                                 RemoteBackend, RetryPolicy, ThreadBackend)
from repro.core.backends.faults import (CORRUPT, DELAY, DROP, ERROR, KILL,
                                        RAISE, TRUNCATE, ChaosProxy,
                                        FaultPlan, FaultRule)
from repro.core.backends.process import ProcessBackend
from repro.core.backends.server import SweepScoringServer
from repro.core.combinator import Combination
from repro.core.executor import CombinationFailed, DryRunExecutor
from repro.core.segment import fragment
from repro.core.tuner import SweepReport
from repro.models.context import SegmentClause

SPACE = {"remat": ("none", "full"), "kernel": ("xla",), "block_q": (16, 32),
         "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}

#: fast, bounded recovery for tests: the sweep must terminate quickly
#: even when a schedule burns the whole budget
POLICY = RetryPolicy(budget_s=15.0, base_s=0.05, cap_s=0.25)


def _plan_bytes(plan):
    d = plan.to_json()
    return json.dumps({"segments": d["segments"], "knobs": d["knobs"]},
                      sort_keys=True).encode()


def _tuner(db, project):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return ComParTuner(cfg, shape, mesh=None, db=db, project=project,
                       mode="new", executor="dryrun", timeout_s=120)


def _sweep(tuner, **kw):
    return tuner.sweep(providers=["tensor_par", "fsdp"], clause_space=SPACE,
                       max_flags=1, use_cache=False, **kw)


def _dead_url():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return f"http://127.0.0.1:{port}"


@pytest.fixture(scope="module")
def baseline():
    """The fault-free sequential truth every chaos sweep must reproduce."""
    plan, rep = _sweep(_tuner(SweepDB(":memory:"), "chaos-base"),
                       backend="sequential")
    return _plan_bytes(plan), rep


# --- FaultPlan: the deterministic schedule -----------------------------------


def test_fault_plan_at_every_limit_semantics():
    plan = FaultPlan({"p": [FaultRule(DROP, at=(2,)),
                            FaultRule(ERROR, every=3, limit=1)]})
    kinds = [(r.kind if r else None) for r in (plan.fires("p")
                                               for _ in range(9))]
    #            1     2     3        4     5     6 (limit hit)
    assert kinds == [None, DROP, ERROR, None, None, None, None, None, None]
    assert plan.events == [("p", 2, DROP), ("p", 3, ERROR)]
    plan.reset()
    assert plan.fires("p") is None and plan.fires("p").kind == DROP


def test_fault_plan_points_count_independently():
    plan = FaultPlan({"a": [FaultRule(DROP, at=(1,))],
                      "b": [FaultRule(ERROR, at=(2,))]})
    assert plan.fires("a").kind == DROP
    assert plan.fires("b") is None
    assert plan.fires("b").kind == ERROR


def test_fault_plan_rate_is_seed_deterministic():
    def draw(seed):
        p = FaultPlan({"p": [FaultRule(DROP, rate=0.5)]}, seed=seed)
        return [p.fires("p") is not None for _ in range(64)]

    a, b = draw(7), draw(7)
    assert a == b, "same seed must replay the same schedule"
    assert a != draw(8), "a different seed should (overwhelmingly) differ"
    assert 8 < sum(a) < 56, "rate=0.5 should fire a middling fraction"


def test_retry_policy_backoff_is_jittered_and_capped():
    pol = RetryPolicy(base_s=0.1, cap_s=0.4, jitter=0.5)
    import random
    pauses = [pol.pause_s(a, rng=random.Random(3)) for a in range(6)]
    assert all(0.0 < p <= 0.4 for p in pauses)
    assert pol.pause_s(10) <= 0.4                      # capped
    flat = RetryPolicy(base_s=0.1, cap_s=0.4, jitter=0.0)
    assert [flat.pause_s(a) for a in range(4)] == [0.1, 0.2, 0.4, 0.4]
    # jitter spreads two "clients" that back off at the same instant
    r1, r2 = random.Random(1), random.Random(2)
    assert pol.pause_s(2, rng=r1) != pol.pause_s(2, rng=r2)


# --- the client retry loop, per wire-level fault kind ------------------------


@pytest.fixture
def server(tmp_path):
    srv = SweepScoringServer(str(tmp_path / "server.db"), workers=2)
    srv.start()
    yield srv
    srv.close()


def _proxy_backend(proxy, **kw):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return RemoteBackend(DryRunExecutor(None), cfg, shape, url=proxy.url,
                         retry=kw.pop("retry", RetryPolicy(
                             budget_s=5.0, base_s=0.01, cap_s=0.05)), **kw)


@pytest.mark.parametrize("rule", [
    FaultRule(ERROR, at=(1,), status=500),
    FaultRule(ERROR, at=(1,), status=503),
    FaultRule(TRUNCATE, at=(1,)),
    FaultRule(CORRUPT, at=(1,)),
    FaultRule(DROP, at=(1,)),
], ids=["http-500", "http-503", "truncated-reply", "corrupt-json",
        "dropped-conn"])
def test_request_retries_every_torn_reply_kind(server, rule):
    """One request of each failure kind, then a clean one: `_request`
    must absorb the fault inside its budget instead of crashing the
    sweep (truncated replies used to raise IncompleteRead uncaught, and
    5xx used to be treated as an unretryable protocol error)."""
    plan = FaultPlan({"proxy:/v1/health": [rule]})
    proxy = ChaosProxy(server.url, plan)
    proxy.start()
    try:
        backend = _proxy_backend(proxy)
        resp = backend._request("/v1/health", timeout=5.0)
        assert resp == {"v": 3, "ok": True} or resp.get("ok") is True
        assert plan.events and plan.events[0][2] == rule.kind
    finally:
        proxy.close()


def test_request_retries_delay_past_timeout(server):
    plan = FaultPlan({"proxy:/v1/health": [FaultRule(DELAY, at=(1,),
                                                     delay_s=1.0)]})
    proxy = ChaosProxy(server.url, plan)
    proxy.start()
    try:
        backend = _proxy_backend(proxy)
        assert backend._request("/v1/health", timeout=0.2).get("ok") is True
    finally:
        proxy.close()


def test_request_gives_up_past_budget_not_forever(server):
    plan = FaultPlan({"proxy": [FaultRule(ERROR, every=1)]})   # always 5xx
    proxy = ChaosProxy(server.url, plan)
    proxy.start()
    try:
        backend = _proxy_backend(proxy, retry=RetryPolicy(
            budget_s=0.3, base_s=0.01, cap_s=0.05))
        t0 = time.monotonic()
        assert backend._request("/v1/health", timeout=5.0) is None
        assert time.monotonic() - t0 < 5.0
    finally:
        proxy.close()


# --- the chaos matrix: full sweeps under wire-fault schedules ----------------

MATRIX = {
    "passthrough": lambda: FaultPlan({}),
    "submit-outage": lambda: FaultPlan(
        {"proxy:/v1/submit": [FaultRule(DROP, at=(1, 2))]}),
    "corrupt-replies": lambda: FaultPlan(
        {"proxy": [FaultRule(CORRUPT, every=3, limit=4)]}),
    "truncated-replies": lambda: FaultPlan(
        {"proxy": [FaultRule(TRUNCATE, at=(2, 4))]}),
    "server-5xx": lambda: FaultPlan(
        {"proxy": [FaultRule(ERROR, every=2, limit=5, status=502)]}),
    "seeded-mixed": lambda: FaultPlan(
        {"proxy": [FaultRule(DROP, rate=0.2), FaultRule(ERROR, rate=0.2)]},
        seed=7),
}


@pytest.mark.parametrize("schedule", sorted(MATRIX), ids=sorted(MATRIX))
def test_chaos_matrix_sweep_is_byte_identical(tmp_path, baseline, schedule):
    """A full remote sweep through a faulty wire: the plan must come out
    byte-identical to the fault-free sequential baseline, with zero
    failed rows and zero poisoned score_cache entries."""
    ref_bytes, ref_rep = baseline
    plan_fp = MATRIX[schedule]()
    srv = SweepScoringServer(str(tmp_path / "srv.db"), workers=2)
    srv.start()
    proxy = ChaosProxy(srv.url, plan_fp)
    proxy.start()
    try:
        plan, rep = _sweep(_tuner(SweepDB(":memory:"), f"chaos-{schedule}"),
                           remote_url=proxy.url, retry=POLICY)
    finally:
        proxy.close()
        srv.close()
    assert _plan_bytes(plan) == ref_bytes
    assert rep.n_failed == 0 and rep.n_transient == 0
    # the server cache holds exactly the deterministic scores — injected
    # failures never wrote a row
    assert srv.db.cache_size() == ref_rep.n_scored
    if schedule != "passthrough":
        assert plan_fp.events, "schedule never fired — the test is vacuous"


def test_server_restart_mid_batch_recovers_byte_identical(tmp_path, baseline):
    """The big one: the scoring server dies after its first compile and a
    fresh process takes over the same db behind the same proxy URL.  The
    client rides resubmit-on-404 + the in-sweep retry round to a plan
    byte-identical to the baseline."""
    ref_bytes, ref_rep = baseline
    db_path = str(tmp_path / "srv.db")
    srv1 = SweepScoringServer(db_path, workers=2)
    srv1.start()
    proxy = ChaosProxy(srv1.url)
    proxy.start()
    srv2_box = {}

    def restart():
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            with srv1._lock:
                if srv1.n_compiled >= 1:
                    break
            time.sleep(0.01)
        srv1.close()
        srv2 = SweepScoringServer(db_path, workers=2)
        srv2.start()
        srv2_box["srv"] = srv2
        proxy.retarget(srv2.url)

    t = threading.Thread(target=restart, daemon=True)
    t.start()
    try:
        plan, rep = _sweep(_tuner(SweepDB(":memory:"), "chaos-restart"),
                           remote_url=proxy.url, retry=POLICY)
        t.join(timeout=120)
        assert "srv" in srv2_box, "server restart never happened"
        assert _plan_bytes(plan) == ref_bytes
        assert rep.n_failed == 0 and rep.n_transient == 0
        # keep-best upsert dedups whatever the dying server double-wrote:
        # the cache ends with exactly the deterministic program set
        assert srv2_box["srv"].db.cache_size() == ref_rep.n_scored
        # the replacement actually served the recovery
        assert srv2_box["srv"].stats()["n_batches"] >= 1
    finally:
        proxy.close()
        if "srv" in srv2_box:
            srv2_box["srv"].close()
        srv1.close()


# --- process backend: seeded worker kills ------------------------------------


def _stack_jobs(cfg, shape, n=2):
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    jobs = []
    for i, provider in enumerate(("fsdp", "tensor_par")[:n]):
        combo = Combination(provider, frozenset(), SegmentClause())
        jobs.append(JobSpec(f"j{i}", seg, combo, segments=(seg.name,)))
    return jobs


def test_process_worker_kill_requeues_and_completes():
    """The FaultPlan's in-process point: the worker holding the first
    dispatched job is terminated — the job requeues onto the surviving
    worker and the sweep still scores everything."""
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    plan = FaultPlan({"process.kill_worker": [FaultRule(KILL, at=(1,))]})
    backend = ProcessBackend(DryRunExecutor(None, timeout_s=120), cfg, shape,
                             workers=2, fault_plan=plan)
    try:
        outs = list(backend.run(_stack_jobs(cfg, shape)))
    finally:
        backend.close()
    assert sorted(o.key for o in outs) == ["j0", "j1"]
    assert all(o.status == "done" for o in outs)
    assert plan.events == [("process.kill_worker", 1, KILL)]
    assert max(o.attempts for o in outs) == 2      # the requeued dispatch


def test_process_worker_kill_every_dispatch_fails_transient_kind_crash():
    """Every dispatch is killed: the job burns max_attempts and comes
    back transient with kind='crash' — and the run terminates instead of
    respawning forever."""
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    plan = FaultPlan({"process.kill_worker": [FaultRule(KILL, every=1)]})
    backend = ProcessBackend(DryRunExecutor(None, timeout_s=120), cfg, shape,
                             workers=2, retry=RetryPolicy(max_attempts=2),
                             fault_plan=plan)
    try:
        outs = list(backend.run(_stack_jobs(cfg, shape, n=1)))
    finally:
        backend.close()
    assert len(outs) == 1
    out = outs[0]
    assert out.status == "failed" and out.transient
    assert out.kind == "crash"
    assert out.attempts == 2


# --- graceful degradation: FallbackBackend -----------------------------------


def test_fallback_rescues_unreachable_server_in_same_run(baseline):
    """Remote down for the whole sweep: every job is re-scored locally
    in the SAME run, the plan matches the baseline byte-for-byte, and
    the degradation is loudly accounted."""
    ref_bytes, ref_rep = baseline
    plan, rep = _sweep(_tuner(SweepDB(":memory:"), "chaos-fallback"),
                       remote_url=_dead_url(), fallback="thread",
                       retry=RetryPolicy(budget_s=0.3, base_s=0.05,
                                         cap_s=0.1))
    assert _plan_bytes(plan) == ref_bytes
    assert rep.n_failed == 0 and rep.n_transient == 0
    assert rep.n_fallback_local == rep.n_combinations
    assert rep.n_fallback_local > 0
    assert "fallback_local" in rep.summary()


def test_fallback_requires_remote_backend():
    with pytest.raises(ValueError, match="fallback"):
        _sweep(_tuner(SweepDB(":memory:"), "chaos-nofb"),
               backend="thread", fallback="thread")


def test_fallback_refuses_remote_as_local():
    from repro.core.backends import make_backend
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    with pytest.raises(ValueError, match="LOCAL"):
        make_backend("remote", DryRunExecutor(None), cfg, shape,
                     remote_url=_dead_url(), fallback="remote")


def test_fallback_passes_protocol_errors_through():
    """Fallback absorbs outages, never bugs: a primary that raises (the
    protocol-error path) must propagate, not degrade to local scoring."""
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()

    class Raising(ThreadBackend):
        def run(self, jobs, incumbents=None):
            raise RuntimeError("HTTP 400 protocol error")
            yield  # pragma: no cover

    primary = Raising(DryRunExecutor(None), cfg, shape)
    local = ThreadBackend(DryRunExecutor(None), cfg, shape)
    fb = FallbackBackend(primary, local)
    with pytest.raises(RuntimeError, match="HTTP 400"):
        list(fb.run(_stack_jobs(cfg, shape)))


# --- in-sweep transient recovery (scheduler retry rounds) --------------------


def _once_flaky(tuner):
    """Wrap the tuner's executor: the FIRST score of every unique
    program raises a transient deadline overrun, the retry succeeds."""
    orig = tuner.executor.score_segment
    seen = set()

    def flaky(cfg, shape, seg, combo, knobs=None):
        key = (seg.name, combo.cid, knobs.kid if knobs else "")
        if key not in seen:
            seen.add(key)
            raise CombinationFailed("deadline 0s exceeded (synthetic)",
                                    transient=True)
        return orig(cfg, shape, seg, combo, knobs=knobs)

    tuner.executor.score_segment = flaky
    return tuner


def test_scheduler_retry_round_rescues_transients(baseline):
    """Every program fails transiently once; the default in-sweep retry
    round re-dispatches and the sweep concludes fault-free — before
    drive() existed this sweep ended with every row failed."""
    ref_bytes, ref_rep = baseline
    tuner = _once_flaky(_tuner(SweepDB(":memory:"), "chaos-retry"))
    plan, rep = _sweep(tuner, backend="sequential")
    assert _plan_bytes(plan) == ref_bytes
    assert rep.n_failed == 0 and rep.n_transient == 0
    assert rep.n_transient_retried == ref_rep.n_scored
    assert "transient_retried" in rep.summary()


def test_scheduler_retry_disabled_keeps_old_behavior(baseline):
    """transient_retries=0 restores the pre-drive contract: transients
    survive to the report (and the failure-kind histogram says so)."""
    _, ref_rep = baseline
    tuner = _once_flaky(_tuner(SweepDB(":memory:"), "chaos-noretry"))
    with pytest.raises(Exception):
        # every program transient-fails and fusion has nothing to fuse
        _sweep(tuner, backend="sequential", transient_retries=0)
    counts = tuner.db.done_count("chaos-noretry")
    assert counts.get("failed", 0) > 0 and counts.get("done", 0) == 0


def test_failure_kinds_histogram_reaches_report():
    """A deterministic failure and a transient one land in different
    failure_kinds buckets."""
    db = SweepDB(":memory:")
    rep = SweepReport("p", n_combinations=2)
    rec = Recorder(db, "p", rep)
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    jobs = _stack_jobs(cfg, shape)
    from repro.core.backends import FAILED, JobOutcome
    g0 = JobGroup(jobs[0].seg, jobs[0].combo, "s0", "e0",
                  members=[("seg", "c0")])
    g1 = JobGroup(jobs[1].seg, jobs[1].combo, "s1", "e1",
                  members=[("seg", "c1")])
    rec.outcome(g0, JobOutcome("j0", FAILED, error="x", transient=True,
                               kind="crash", attempts=2))
    rec.outcome(g1, JobOutcome("j1", FAILED, error="y"))
    assert rep.failure_kinds == {"crash": 1, "deterministic": 1}
    assert rep.n_transient_retried == 1
    assert "failure_kinds" in rep.summary()


# --- recorder flush crash ----------------------------------------------------


def test_recorder_flush_crash_then_recovery(tmp_path):
    """The 'crash the recorder flush' injection point: the first flush
    raises (rows stay buffered), the retry lands every row exactly
    once."""
    db = SweepDB(str(tmp_path / "rec.db"))
    db.open_project("p", "new")
    rep = SweepReport("p", n_combinations=1)
    plan = FaultPlan({"recorder.flush": [FaultRule(RAISE, at=(1,))]})
    rec = Recorder(db, "p", rep, fault_plan=plan, batch=1000)
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    job = _stack_jobs(cfg, shape, n=1)[0]
    db.register("p", job.seg.name, job.combo)
    g = JobGroup(job.seg, job.combo, "sig", "ec",
                 members=[(job.seg.name, job.combo.cid)])
    from repro.core.backends import DONE, JobOutcome
    rec.outcome(g, JobOutcome("j0", DONE, cost={"total_s": 1.0}))
    with pytest.raises(RuntimeError, match="fault injection"):
        rec.flush()
    assert db.results("p") == [] or \
        all(r["status"] != "done" for r in db.results("p"))
    rec.flush()                                    # second flush lands
    rows = [r for r in db.results("p") if r["status"] == "done"]
    assert len(rows) == 1
    assert plan.events == [("recorder.flush", 1, RAISE)]
