"""The hierarchical kernel-schedule axis (inner sweep level).

Covers: pallas-vs-oracle numerics across the swept tile grid, the
clause-default <-> op-signature round-trip (the skew regression), the
versioned ``kernel_cache`` (round-trip, stale-version recalibration,
warm sweeps re-benchmark nothing), and the exactness contract of the
outer filter — ``kernel_top_k=len(grid)`` fuses a plan byte-identical
to the exhaustive clause sweep, and ``prune=True`` with the
kernel-aware floor never changes the plan.
"""
import dataclasses
import inspect
import json
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.executor import DryRunExecutor
from repro.kernels import ops, ref
from repro.kernels.autotune import (DEFAULT_KERNEL_SPACE,
                                    KERNEL_CACHE_VERSION, KernelTuning,
                                    OP_FIELDS, cache_key, clause_schedule,
                                    measure_op, op_variants, schedule_key,
                                    segment_ops)
from repro.models.context import SegmentClause


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(key), shape).astype(dtype)


def _plan_bytes(plan):
    d = plan.to_json()
    return json.dumps({"segments": d["segments"], "knobs": d["knobs"]},
                      sort_keys=True).encode()


# single-point base space + the swept kernel grid (T = 2*2*2 = 8)
BASE = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
        "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
KSPACE = {"kernel": ("xla", "pallas"), "block_q": (16, 32),
          "block_k": (16, 32)}


def _merged():
    m = dict(BASE)
    m.update(KSPACE)
    return m


def _ktuner(db, project):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return ComParTuner(cfg, shape, mesh=None, db=db, project=project,
                       mode="new", executor="dryrun", timeout_s=120)


def _ksweep(tuner, **kw):
    return tuner.sweep(providers=["tensor_par", "fsdp"], max_flags=1, **kw)


# --- numerics across the swept tile grid -------------------------------------

@pytest.mark.parametrize("block_q,block_k",
                         [(16, 16), (16, 32), (32, 16), (32, 64)])
def test_flash_attention_tile_grid_allclose(block_q, block_k):
    B, S, H, KV, D = 1, 64, 4, 2, 16
    q = rand(1, (B, S, H, D))
    k = rand(2, (B, S, KV, D))
    v = rand(3, (B, S, KV, D))
    out = ops.flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    expect = ref.flash_attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                     v.swapaxes(1, 2)).swapaxes(1, 2)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_mlstm_tile_grid_allclose(chunk):
    B, H, S, dh = 1, 2, 64, 16
    q = rand(1, (B, H, S, dh)) * dh ** -0.5
    k = rand(2, (B, H, S, dh))
    v = rand(3, (B, H, S, dh))
    li = rand(4, (B, H, S))
    lf = -jax.nn.softplus(-rand(5, (B, H, S)))
    out = ops.mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    expect = ref.mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(out, expect, atol=5e-4, rtol=5e-3)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_rglru_tile_grid_allclose(chunk):
    B, S, dr = 1, 64, 32
    la = -jnp.abs(rand(1, (B, S, dr))) * 0.2
    b = rand(2, (B, S, dr))
    out = ops.rglru(la, b, chunk=chunk)
    expect = ref.rglru_ref(la, b)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-4)


# --- clause defaults <-> op signatures (the skew regression) -----------------

def test_clause_defaults_round_trip_op_signatures():
    """An outer-space point omitting a tile field and an op invoked with
    its signature default must land on the SAME schedule — the clause
    defaults and the op defaults may never skew again."""
    cl = SegmentClause()
    d = lambda fn, name: inspect.signature(fn).parameters[name].default
    assert d(ops.flash_attention, "block_q") == cl.block_q
    assert d(ops.flash_attention, "block_k") == cl.block_k
    assert d(ops.flash_decode, "block_k") == cl.block_k
    assert d(ops.mlstm_chunkwise, "chunk") == cl.mlstm_chunk
    assert d(ops.rglru, "chunk") == cl.mlstm_chunk
    from repro.kernels.flash_attention import flash_attention_fwd
    assert d(flash_attention_fwd, "block_q") == cl.block_q
    assert d(flash_attention_fwd, "block_k") == cl.block_k
    from repro.kernels.rglru import rglru_fwd
    assert d(rglru_fwd, "chunk") == cl.mlstm_chunk


def test_op_variants_fall_back_to_clause_defaults():
    cl = SegmentClause()
    for op, fields in OP_FIELDS.items():
        variants = op_variants(op, {})
        assert variants == [{f: getattr(cl, f) for f in fields}]


def test_default_kernel_space_covers_every_tuned_field():
    tuned = {f for fields in OP_FIELDS.values() for f in fields}
    assert tuned == set(DEFAULT_KERNEL_SPACE)


# --- schedule keys, dispatch-site counts, projection -------------------------

def test_schedule_key_is_order_canonical():
    a = schedule_key({"kernel": "xla", "block_q": 16})
    b = schedule_key({"block_q": 16, "kernel": "xla"})
    assert a == b == "block_q=16,kernel=xla"
    cl = SegmentClause(kernel="xla", block_q=16)
    assert clause_schedule(cl, ("kernel", "block_q")) == a


def test_segment_ops_mirrors_dispatch_sites():
    cfg = get_arch("granite-8b").smoke()
    train = get_shape("train_4k").smoke()
    decode = get_shape("decode_32k").smoke()
    seg = types.SimpleNamespace(kind="stack", name="g0",
                                pattern=("attn_g", "mlp"), repeats=2)
    assert segment_ops(cfg, train, seg) == {"flash_attention": 2}
    assert segment_ops(cfg, decode, seg) == {"flash_decode": 2}
    # windowed decode takes the ring-buffer path — no kernel dispatch
    windowed = dataclasses.replace(cfg, window_size=16)
    assert segment_ops(windowed, decode, seg) == {}
    # non-stack segments have no tuned ops
    embed = types.SimpleNamespace(kind="embed", name="embed",
                                  pattern=(), repeats=1)
    assert segment_ops(cfg, train, embed) == {}
    rec = types.SimpleNamespace(kind="stack", name="r0",
                                pattern=("rec", "mlstm", "attn_l"), repeats=1)
    assert segment_ops(cfg, train, rec) == \
        {"rglru": 1, "mlstm_chunkwise": 1, "flash_attention": 1}


def test_keeps_and_floor_project_the_clause():
    kt = KernelTuning()
    kt.fields["g0"] = ("block_k", "block_q", "kernel")
    kept = SegmentClause(kernel="xla", block_q=16, block_k=16)
    key = clause_schedule(kept, kt.fields["g0"])
    kt.surviving["g0"] = {key}
    kt.floors["g0"] = {key: 123.0}
    assert kt.keeps("g0", kept)
    assert kt.floor_flops("g0", kept) == 123.0
    dropped = SegmentClause(kernel="pallas", block_q=16, block_k=16)
    assert not kt.keeps("g0", dropped)
    assert kt.floor_flops("g0", dropped) == 0.0
    # untuned segments stay unrestricted with a trivially-sound floor
    assert kt.keeps("other", dropped)
    assert kt.floor_flops("other", dropped) == 0.0


# --- kernel_cache: round-trip + stale-version recalibration ------------------

def test_kernel_cache_round_trip_and_stale_version(tmp_path):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    ex = DryRunExecutor(None)
    space = {"kernel": ("xla", "pallas"), "block_q": (16,),
             "block_k": (16, 32)}
    db = SweepDB(str(tmp_path / "kc.db"))
    res1, timed1, cached1 = measure_op(db, "flash_attention", cfg, shape,
                                       space, ex)
    assert timed1 == len(res1) == 4 and cached1 == 0
    assert all(e["status"] == "done" for e in res1.values())
    # second pass: every variant resolves from the cache, zero timed
    res2, timed2, cached2 = measure_op(db, "flash_attention", cfg, shape,
                                       space, ex)
    assert timed2 == 0 and cached2 == 4
    assert {k: e["time_s"] for k, e in res2.items()} == \
        {k: e["time_s"] for k, e in res1.items()}
    # stale-version rows are unaddressable: a db holding only v0 rows
    # (as after a version bump) forces full re-measurement
    db0 = SweepDB(str(tmp_path / "stale.db"))
    key = cache_key("flash_attention", cfg, shape, ex.cache_tag)
    old = key.replace(f"kernel:v{KERNEL_CACHE_VERSION}:", "kernel:v0:")
    assert old != key
    db0.kernel_put_many(old, res1)
    res3, timed3, cached3 = measure_op(db0, "flash_attention", cfg, shape,
                                       space, ex)
    assert cached3 == 0 and timed3 == len(res3) == 4


def test_kernel_cache_persists_failed_rows(tmp_path):
    db = SweepDB(str(tmp_path / "kf.db"))
    db.kernel_put_many("kernel:v1:t:op:d", {
        "kernel=pallas": {"status": "failed", "error": "boom"},
        "kernel=xla": {"status": "done", "time_s": 1.5, "flops": 2.0}})
    got = db.kernel_get("kernel:v1:t:op:d")
    assert got["kernel=pallas"]["status"] == "failed"
    assert got["kernel=pallas"]["error"] == "boom"
    assert got["kernel=xla"] == {"status": "done", "time_s": 1.5,
                                 "flops": 2.0, "error": ""}


# --- e2e: the kernel axis through the outer engine ---------------------------

@pytest.fixture(scope="module")
def kernel_axis_runs():
    db = SweepDB(":memory:")
    plan_ex, rep_ex = _ksweep(_ktuner(db, "exhaustive"),
                              clause_space=_merged(), use_cache=True,
                              prune=False)
    plan_k, rep_k = _ksweep(_ktuner(db, "topk-all"), clause_space=BASE,
                            kernel_space=KSPACE, kernel_top_k=8,
                            use_cache=True, prune=False)
    return db, plan_ex, rep_ex, plan_k, rep_k


def test_top_k_full_grid_byte_identical_to_exhaustive(kernel_axis_runs):
    _, plan_ex, rep_ex, plan_k, rep_k = kernel_axis_runs
    assert _plan_bytes(plan_k) == _plan_bytes(plan_ex)
    assert rep_k.n_combinations == rep_ex.n_combinations
    assert rep_k.kernel_tuning is not None
    assert rep_k.kernel_tuning["n_variants"] == 8
    assert rep_ex.kernel_tuning is None


def test_warm_kernel_cache_zero_rebenchmarks(kernel_axis_runs):
    db, _, _, plan_k, _ = kernel_axis_runs
    plan2, rep2 = _ksweep(_ktuner(db, "warm"), clause_space=BASE,
                          kernel_space=KSPACE, kernel_top_k=8,
                          use_cache=True, prune=False)
    assert rep2.kernel_tuning["n_timed"] == 0
    assert rep2.kernel_tuning["n_cached"] == 8
    assert rep2.n_scored == 0          # outer score cache is warm too
    assert _plan_bytes(plan2) == _plan_bytes(plan_k)


def test_top_k_restricts_outer_rows(kernel_axis_runs):
    db, _, rep_ex, _, _ = kernel_axis_runs
    plan, rep = _ksweep(_ktuner(db, "topk2"), clause_space=BASE,
                        kernel_space=KSPACE, kernel_top_k=2,
                        use_cache=True, prune=False)
    kt = rep.kernel_tuning
    assert kt["top_k"] == 2
    affected = [s for s, d in kt["per_segment"].items() if d["kept"] == 2]
    assert affected                     # at least one tuned stack segment
    for d in kt["per_segment"].values():
        assert d["schedules"] == 8 and d["kept"] == 2
    assert rep.n_combinations < rep_ex.n_combinations
    # the surviving plan picks a schedule the exhaustive sweep also saw
    assert plan.segments


def test_prune_with_kernel_floor_byte_identical():
    db = SweepDB(":memory:")
    plan_ref, _ = _ksweep(_ktuner(db, "unpruned"), clause_space=BASE,
                          kernel_space=KSPACE, kernel_top_k=8,
                          use_cache=True, prune=False)
    pruned = _ktuner(db, "pruned")
    plan_p, rep_p = _ksweep(pruned, clause_space=BASE, kernel_space=KSPACE,
                            kernel_top_k=8, use_cache=True, prune=True)
    assert _plan_bytes(plan_p) == _plan_bytes(plan_ref)
    # every bound (with its kernel floor) certifies under the measurement
    tightness = pruned.audit_soundness()
    assert tightness


def test_kernel_space_string_validation():
    t = _ktuner(SweepDB(":memory:"), "bad")
    with pytest.raises(ValueError):
        t.sweep(providers=["fsdp"], clause_space=BASE,
                kernel_space="fastest", max_flags=0)
