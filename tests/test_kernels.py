"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes.

All Pallas kernels run in interpret mode (CPU container; TPU is the
compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.key(key), shape) * scale
            ).astype(dtype)


# --- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 4, 4, 128, 32),     # MHA
    (2, 4, 2, 128, 32),     # GQA 2:1
    (1, 8, 1, 256, 16),     # MQA
])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_allclose(B, H, KV, S, D, window, dtype):
    q = rand(1, (B, H, S, D), dtype)
    k = rand(2, (B, KV, S, D), dtype)
    v = rand(3, (B, KV, S, D), dtype)
    from repro.kernels.flash_attention import flash_attention_fwd
    out = flash_attention_fwd(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_model_layout_and_grad():
    B, S, H, KV, D = 1, 64, 4, 2, 16
    q = rand(1, (B, S, H, D))
    k = rand(2, (B, S, KV, D))
    v = rand(3, (B, S, KV, D))
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    assert out.shape == (B, S, H, D)
    g = jax.grad(lambda *a: ops.flash_attention(
        *a, block_q=32, block_k=32).sum(), argnums=(0, 1, 2))(q, k, v)
    for t in g:
        assert not np.any(np.isnan(t))


# --- flash decode ------------------------------------------------------------

@pytest.mark.parametrize("pos", [0, 17, 255])
@pytest.mark.parametrize("KV", [1, 2, 4])
def test_flash_decode_allclose(pos, KV):
    B, H, S, D = 2, 4, 256, 32
    q = rand(1, (B, H, D))
    k = rand(2, (B, S, KV, D))
    v = rand(3, (B, S, KV, D))
    out = ops.flash_decode(q, k, v, jnp.int32(pos), block_k=64)
    expect = ref.flash_decode_ref(q, k, v, pos)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_decode_lse_combine():
    """Seq-sharded decode: combining per-shard (out, lse) must equal the
    unsharded result — the contract the serving path relies on."""
    B, H, S, D = 1, 2, 128, 16
    q = rand(1, (B, H, D))
    k = rand(2, (B, S, 1, D))
    v = rand(3, (B, S, 1, D))
    pos = 127
    full = ref.flash_decode_ref(q, k, v, pos)
    # two shards of the sequence; shard 1 positions offset by S//2
    o1, l1 = ops.flash_decode(q, k[:, :S // 2], v[:, :S // 2],
                              jnp.int32(pos), block_k=32, return_lse=True)
    o2, l2 = ops.flash_decode(q, k[:, S // 2:], v[:, S // 2:],
                              jnp.int32(pos - S // 2), block_k=32,
                              return_lse=True)
    w1 = jnp.exp(l1 - jnp.logaddexp(l1, l2))[..., None]
    combined = o1 * w1 + o2 * (1 - w1)
    np.testing.assert_allclose(combined, full, atol=2e-5, rtol=2e-5)


# --- rglru -------------------------------------------------------------------

@pytest.mark.parametrize("B,S,dr,chunk", [
    (1, 64, 32, 16), (2, 128, 96, 64), (1, 100, 48, 32),  # odd S
])
def test_rglru_allclose(B, S, dr, chunk):
    la = -jnp.abs(rand(1, (B, S, dr))) * 0.2
    b = rand(2, (B, S, dr))
    out = ops.rglru(la, b, chunk=chunk)
    expect = ref.rglru_ref(la, b)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-4)


def test_rglru_grad():
    la = -jnp.abs(rand(1, (1, 32, 8))) * 0.2
    b = rand(2, (1, 32, 8))
    g = jax.grad(lambda la, b: ops.rglru(la, b, chunk=16).sum(),
                 argnums=(0, 1))(la, b)
    ge = jax.grad(lambda la, b: ref.rglru_ref(la, b).sum(),
                  argnums=(0, 1))(la, b)
    for a, e in zip(g, ge):
        np.testing.assert_allclose(a, e, atol=1e-4, rtol=1e-3)


# --- mlstm -------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,dh,chunk", [
    (1, 2, 64, 16, 16), (2, 2, 128, 32, 32), (1, 1, 96, 16, 32),
])
def test_mlstm_allclose(B, H, S, dh, chunk):
    q = rand(1, (B, H, S, dh)) * dh ** -0.5
    k = rand(2, (B, H, S, dh))
    v = rand(3, (B, H, S, dh))
    li = rand(4, (B, H, S))
    lf = -jax.nn.softplus(-rand(5, (B, H, S)))
    out = ops.mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    expect = ref.mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(out, expect, atol=5e-4, rtol=5e-3)


def test_mlstm_chunk_invariance():
    """Chunk size is a pure performance knob — results must not change."""
    B, H, S, dh = 1, 2, 64, 16
    q = rand(1, (B, H, S, dh)) * dh ** -0.5
    k = rand(2, (B, H, S, dh))
    v = rand(3, (B, H, S, dh))
    li = rand(4, (B, H, S))
    lf = -jax.nn.softplus(-rand(5, (B, H, S)))
    o16 = ops.mlstm_chunkwise(q, k, v, li, lf, chunk=16)
    o64 = ops.mlstm_chunkwise(q, k, v, li, lf, chunk=64)
    np.testing.assert_allclose(o16, o64, atol=5e-4, rtol=5e-3)


# --- rmsnorm -----------------------------------------------------------------

@pytest.mark.parametrize("shape", [(32, 64), (128, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_allclose(shape, dtype):
    x = rand(1, shape, dtype)
    s = rand(2, shape[-1:])
    out = ops.rmsnorm(x, s)
    expect = ref.rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)
