"""Calibrated machine model + tightened exact pruning.

Invariants: a MachineProfile round-trips through JSON and the
machine_cache with a stable content id; the Hardware view falls back to
the built-in constants for anything unmeasured; ``combo_lower_bound``
is monotone in the hardware constants, the remat clause and the
microbatches knob; pruning with ``slack_s`` never changes a Viterbi
argmin (brute-force over random chains); and end-to-end, a pinned
compute-dominated profile prunes strictly more rows than the constant
model while fusing a byte-identical plan — with every surviving row
passing the soundness audit (bound <= measured score).
"""
import json
import random

import pytest
from dataclasses import replace

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.backends.base import IncumbentTracker, JobSpec
from repro.core.combinator import Combination, GlobalKnobs
from repro.core.cost_model import V5E, combo_lower_bound
from repro.core.machine import (PROFILE_VERSION, MachineProfile, calibrate,
                                hardware_from_profile, load_or_calibrate,
                                profile_key, resolve_machine)
from repro.core.meshspec import LOCAL, MeshSpec, default_mesh_space
from repro.core.segment import fragment
from repro.models.context import SegmentClause

SPACE = {"remat": ("none", "full"), "kernel": ("xla",), "block_q": (16,),
         "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}

#: hand-pinned profile: compute floor dominates every score (peak 5
#: orders below v5e, bandwidths at the constant), so the bound is tight
#: and the demonstration below is deterministic on any host.
SLOW = MachineProfile(platform="synthetic", device_kind="slow-host",
                      n_devices=1, peak_flops={"bfloat16": 1.0e9})


def _combo(remat="none"):
    return Combination("fsdp", frozenset(), SegmentClause(remat=remat))


# --- MachineProfile content + cache ------------------------------------------

def test_profile_roundtrip_and_pid():
    p = MachineProfile(platform="cpu", device_kind="cpu", n_devices=2,
                       peak_flops={"bfloat16": 1e10, "float32": 5e9},
                       hbm_bw=1e9,
                       collectives={"psum:data=2:1024":
                                    {"s": 1e-4, "bytes": 1024.0,
                                     "bytes_s": 1024.0 / 1e-4}})
    q = MachineProfile.from_json(json.loads(json.dumps(p.to_json())))
    assert q == p and q.pid == p.pid
    assert p.key == profile_key("cpu", "cpu", 2) == "machine:v1:cpu:cpu:2"
    # the pid is a content hash: any measured value moves it
    assert replace(p, hbm_bw=2e9).pid != p.pid


def test_machine_cache_persist_and_reload(monkeypatch):
    db = SweepDB(":memory:")
    prof = load_or_calibrate(db, tiny=True)
    assert db.machine_get(prof.key) == prof.to_json()

    # second resolve must be served from machine_cache, not re-measured
    def boom(*a, **kw):
        raise AssertionError("recalibrated despite a fresh cached profile")
    monkeypatch.setattr("repro.core.machine.calibrate", boom)
    again = load_or_calibrate(db, tiny=True)
    assert again.pid == prof.pid


def test_stale_profile_version_recalibrates():
    db = SweepDB(":memory:")
    prof = calibrate(tiny=True)
    stale = dict(prof.to_json(), version=PROFILE_VERSION - 1)
    db.machine_put(prof.key, "stale", stale)
    fresh = load_or_calibrate(db, tiny=True)
    assert fresh.version == PROFILE_VERSION
    assert db.machine_get(prof.key)["version"] == PROFILE_VERSION


def test_hardware_view_fallbacks():
    hw = hardware_from_profile(SLOW)
    assert hw.peak_flops == 1.0e9                  # measured
    assert hw.hbm_bw == V5E.hbm_bw                 # unmeasured -> constant
    assert hw.link_bw == V5E.link_bw
    assert hw.name.startswith("cal1-synthetic-")
    assert SLOW.pid[:8] in hw.name                 # cache-tag isolation
    # best dtype on the ladder wins
    two = replace(SLOW, peak_flops={"bfloat16": 1e9, "float32": 3e9})
    assert hardware_from_profile(two).peak_flops == 3e9


def test_resolve_machine_dispatch():
    db = SweepDB(":memory:")
    assert resolve_machine(None, db) is None
    assert resolve_machine(V5E, db) is V5E
    assert resolve_machine(SLOW, db).name == hardware_from_profile(SLOW).name
    auto = resolve_machine("auto", db)
    assert auto is not None and db.machine_get(auto_key(db)) is not None
    with pytest.raises(ValueError):
        resolve_machine(42, db)


def auto_key(db):
    import jax
    devs = jax.devices()
    return profile_key(jax.default_backend(),
                       getattr(devs[0], "device_kind", "")
                       or jax.default_backend(), len(devs))


# --- mesh-topology presets ---------------------------------------------------

def test_default_mesh_space():
    assert default_mesh_space(1) == [LOCAL]
    assert default_mesh_space(4) == [
        LOCAL, MeshSpec.of(data=4), MeshSpec.of(data=2, model=2)]
    keys = [m.key() for m in default_mesh_space(8)]
    # data-major factor order: (4,2) before (2,4)
    assert keys == ["local", "data8[any]", "data4xmodel2[any]",
                    "data2xmodel4[any]"]
    assert default_mesh_space(6, device_kind="tpu")[1] == \
        MeshSpec.of("tpu", data=6)


# --- bound structure ---------------------------------------------------------

def _stack_seg(cfg):
    return next(s for s in fragment(cfg) if s.kind == "stack")


def test_bound_monotone_in_hardware():
    cfg = get_arch("recurrentgemma-2b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = _stack_seg(cfg)
    slow = hardware_from_profile(SLOW)
    for remat in ("none", "dots", "full"):
        b_const = combo_lower_bound(cfg, shape, seg, _combo(remat), 1, V5E)
        b_slow = combo_lower_bound(cfg, shape, seg, _combo(remat), 1, slow)
        assert 0 < b_const < b_slow     # slower machine -> larger floor
    # more chips can only lower the floor
    assert combo_lower_bound(cfg, shape, seg, _combo(), 4, V5E) < \
        combo_lower_bound(cfg, shape, seg, _combo(), 1, V5E)


def test_bound_monotone_in_remat_and_microbatches():
    cfg = get_arch("recurrentgemma-2b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = _stack_seg(cfg)
    b = {r: combo_lower_bound(cfg, shape, seg, _combo(r), 1, V5E)
         for r in ("none", "dots", "full")}
    assert b["none"] <= b["dots"] <= b["full"]     # full remat reruns fwd
    # grad-accum re-streams the weights once per microbatch trip, so the
    # traffic floor scales with the knob (memory-bound under V5E)
    b1 = combo_lower_bound(cfg, shape, seg, _combo(), 1, V5E,
                           knobs=GlobalKnobs(microbatches=1))
    b4 = combo_lower_bound(cfg, shape, seg, _combo(), 1, V5E,
                           knobs=GlobalKnobs(microbatches=4))
    assert b4 > b1


def test_collective_floor_needs_batch_sharding():
    cfg = get_arch("recurrentgemma-2b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = _stack_seg(cfg)
    meshless = combo_lower_bound(cfg, shape, seg, _combo(), 4, V5E)
    meshed = combo_lower_bound(cfg, shape, seg, _combo(), 4, V5E,
                               mesh_axes={"data": 4})
    assert meshed >= meshless           # adding a floor can only tighten


# --- slack pruning is exact (brute force) ------------------------------------

def _viterbi(options, trans):
    """min over chains of sum(total) + sum(transition); returns cost."""
    prev = {i: c[1] for i, c in enumerate(options[0])}
    for si in range(1, len(options)):
        cur = {}
        for j, (_, tj) in enumerate(options[si]):
            cur[j] = min(prev[i] + trans[si - 1][i][j] for i in prev) + tj
        prev = cur
    return min(prev.values())


def test_slack_prune_never_changes_viterbi_argmin():
    rng = random.Random(0)
    for trial in range(200):
        n_segs = rng.randint(2, 4)
        b_max = rng.uniform(0.0, 0.5)
        options = []                      # per seg: [(bound, total)]
        for _ in range(n_segs):
            opts = []
            for _ in range(rng.randint(2, 4)):
                total = rng.uniform(1.0, 3.0)
                opts.append((total * rng.uniform(0.3, 1.0), total))
            options.append(opts)
        trans = [[[rng.uniform(0.0, b_max)
                   for _ in options[s + 1]] for _ in options[s]]
                 for s in range(n_segs - 1)]
        slack = (n_segs - 1) * b_max

        # emulate the engine: cheapest-bound-first, prune against the
        # incumbent per segment with the slack allowance (margin 0)
        jobs = sorted(((s, i) for s in range(n_segs)
                       for i in range(len(options[s]))),
                      key=lambda si: options[si[0]][si[1]][0])
        tracker = IncumbentTracker(prune=True, prune_margin=0.0)
        kept = [set() for _ in range(n_segs)]
        for s, i in jobs:
            bound, total = options[s][i]
            job = JobSpec(f"{s}/{i}", None, None, segments=(str(s),),
                          bound_s=bound, slack_s=slack)
            if tracker.pruned(job):
                continue
            kept[s].add(i)
            tracker.observe((str(s),), total)

        assert all(kept), f"trial {trial}: a segment lost every option"
        pruned_opts = [[options[s][i] for i in sorted(kept[s])]
                       for s in range(n_segs)]
        pruned_trans = [[[trans[s][i][j] for j in sorted(kept[s + 1])]
                         for i in sorted(kept[s])]
                        for s in range(n_segs - 1)]
        full = _viterbi(options, trans)
        survived = _viterbi(pruned_opts, pruned_trans)
        assert survived == pytest.approx(full, rel=0, abs=1e-12), \
            f"trial {trial}: pruning changed the chain argmin"


# --- process backend start method --------------------------------------------

def test_resolve_ctx_start_methods():
    import multiprocessing as mp

    from repro.core.backends.process import _resolve_ctx
    assert _resolve_ctx("spawn").get_start_method() == "spawn"
    auto = _resolve_ctx("auto").get_start_method()
    if "forkserver" in mp.get_all_start_methods():
        assert auto == "forkserver"
    else:
        assert auto == "spawn"


# --- end to end: calibrated pruning ------------------------------------------

@pytest.fixture(scope="module")
def calibrated_vs_constant():
    cfg = get_arch("recurrentgemma-2b").smoke()
    shape = get_shape("train_4k").smoke()
    out = {}
    for label, machine in (("const", None), ("slow", SLOW)):
        db = SweepDB(":memory:")
        t = ComParTuner(cfg, shape, db=db, project="p", mode="new",
                        executor="dryrun", machine=machine, timeout_s=120)
        plan, rep = t.sweep(providers=["fsdp"], clause_space=SPACE,
                            max_flags=0, prune=True, prune_margin=0.0)
        ref = ComParTuner(cfg, shape, db=db, project="ref", mode="new",
                          executor="dryrun", machine=machine, timeout_s=120)
        ref_plan, ref_rep = ref.sweep(providers=["fsdp"], clause_space=SPACE,
                                      max_flags=0, prune=False)
        out[label] = (t, plan, rep, ref_plan, ref_rep)
    return out


def test_calibrated_prunes_strictly_more(calibrated_vs_constant):
    _, _, r_const, _, _ = calibrated_vs_constant["const"]
    _, _, r_slow, _, _ = calibrated_vs_constant["slow"]
    assert r_slow.n_pruned > r_const.n_pruned
    # pruned rows are compiles skipped, not rows lost
    assert r_slow.n_scored < r_const.n_scored
    assert r_slow.n_done + r_slow.n_pruned == r_const.n_done


def test_pruned_plan_matches_exhaustive(calibrated_vs_constant):
    for label in ("const", "slow"):
        _, plan, _, ref_plan, _ = calibrated_vs_constant[label]
        assert {k: c.cid for k, c in plan.segments.items()} == \
            {k: c.cid for k, c in ref_plan.segments.items()}, label


def test_soundness_audit_and_tightness(calibrated_vs_constant):
    for label in ("const", "slow"):
        t, _, rep, _, _ = calibrated_vs_constant[label]
        table = t.audit_soundness()        # raises on any violation
        assert rep.bound_tightness and set(table) == set(rep.bound_tightness)
        for st in table.values():
            assert 0.0 <= st["mean"] <= st["max"] <= 1.0 + 1e-9
        assert "bound_tightness=" in rep.summary()
    # the pinned slow profile must actually be the tighter model
    t_c, _, rep_c, _, _ = calibrated_vs_constant["const"]
    t_s, _, rep_s, _, _ = calibrated_vs_constant["slow"]
    assert rep_s.bound_tightness["stack"]["max"] > \
        rep_c.bound_tightness["stack"]["max"]


def test_calibrated_scores_never_share_constant_cache(calibrated_vs_constant):
    # same DB reuse happens per-profile only: the ref sweep resolves from
    # cache under its own hardware tag, so scored-counts stay per-model
    _, _, _, _, ref_const = calibrated_vs_constant["const"]
    assert ref_const.n_cached > 0          # same-tag reuse works...
    t_slow, _, _, _, _ = calibrated_vs_constant["slow"]
    db = SweepDB(":memory:")
    cross = ComParTuner(t_slow.cfg, t_slow.shape, db=db, project="x",
                        mode="new", executor="dryrun", machine=SLOW,
                        timeout_s=120)
    assert cross.executor.cache_tag != "dryrun:tpu-v5e"  # ...cross-tag can't
