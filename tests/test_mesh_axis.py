"""The mesh/topology axis: MeshSpec wire format + mesh_space sweeps.

Invariants: a MeshSpec round-trips through JSON and materializes against
local devices; a worker-rebuilt mesh scores byte-identical costs to the
parent-built mesh; the scoring server rejects unsatisfiable meshes with
HTTP 400 (a protocol error, never a retried transient); a
``sweep(mesh_space=[...])`` registers per-point rows, chooses the plan's
mesh by joint argmin, shares cache rows with repeat (and fixed-mesh)
sweeps, and fuses byte-identically across sequential/process/remote
backends — the meshed-sweep thread-backend fallback is gone.

Multi-device cases skip below their device requirement; CI runs them
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the ``mesh-axis`` job).
"""
import json
import urllib.error
import urllib.request

import jax
import pytest

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.backends import JobSpec, env_key, mesh_key
from repro.core.combinator import Combination, GlobalKnobs, row_cid
from repro.core.executor import DryRunExecutor
from repro.core.meshspec import (LOCAL, MeshSpec, MeshUnsatisfiable,
                                 as_mesh_point, cached_mesh)
from repro.core.segment import Segment, fragment
from repro.models.context import SegmentClause

N_DEV = len(jax.devices())

SPACE = {"remat": ("none", "full"), "kernel": ("xla",), "block_q": (16,),
         "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}


def _plan_bytes(plan):
    """Byte-identity of the fused decisions: segments, knobs AND the
    chosen mesh point."""
    d = plan.to_json()
    return json.dumps({"segments": d["segments"], "knobs": d["knobs"],
                       "mesh": d["mesh"]}, sort_keys=True).encode()


def _tuner(db, project, mesh=None, mode="new"):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return ComParTuner(cfg, shape, mesh=mesh, db=db, project=project,
                       mode=mode, executor="dryrun", timeout_s=120)


def _sweep(tuner, **kw):
    kw.setdefault("use_cache", False)
    return tuner.sweep(providers=["tensor_par", "fsdp"], clause_space=SPACE,
                       max_flags=1, **kw)


# --- the MeshSpec wire format ------------------------------------------------


def test_meshspec_roundtrip_and_content_keys():
    spec = MeshSpec.of(data=2, model=2, device_kind="cpu")
    wire = json.loads(json.dumps(spec.to_json()))
    assert wire == {"axes": [["data", 2], ["model", 2]],
                    "device_kind": "cpu"}
    assert MeshSpec.from_json(wire) == spec
    assert spec.n_devices == 4 and spec.axis_names == ("data", "model")
    assert spec.key() == "data2xmodel2[cpu]"
    # content id: stable, axis-ORDER-sensitive (mesh shape is ordered),
    # device-kind-sensitive
    assert spec.mid == MeshSpec.of(data=2, model=2, device_kind="cpu").mid
    assert spec.mid != MeshSpec.of(model=2, data=2, device_kind="cpu").mid
    assert spec.mid != MeshSpec.of(data=2, model=2).mid
    # the local point
    assert LOCAL.is_local and LOCAL.mid == "local" and LOCAL.to_mesh() is None
    assert MeshSpec.from_json(json.loads(json.dumps(LOCAL.to_json()))) == LOCAL


def test_as_mesh_point_coercions():
    assert as_mesh_point(None) == LOCAL
    assert as_mesh_point({"data": 2}) == MeshSpec.of(data=2)
    assert as_mesh_point({"axes": [["data", 2]], "device_kind": "cpu"}) \
        == MeshSpec.of(data=2, device_kind="cpu")
    live = MeshSpec.of(data=1).to_mesh()
    # live meshes derive an unconstrained spec: the same topology hashes
    # the same whether it arrived live or declarative (cache sharing)
    assert as_mesh_point(live) == MeshSpec.of(data=1)
    with pytest.raises(TypeError):
        as_mesh_point("data=2")


def test_meshspec_materializes_and_rejects_oversized():
    mesh = MeshSpec.of(data=1).to_mesh()
    assert tuple(mesh.axis_names) == ("data",) and mesh.devices.size == 1
    # memoized materialization returns one mesh per content key
    assert cached_mesh(MeshSpec.of(data=1)) is cached_mesh(MeshSpec.of(data=1))
    huge = MeshSpec.of(data=1 << 20)
    with pytest.raises(MeshUnsatisfiable, match="device"):
        huge.check_local()
    with pytest.raises(MeshUnsatisfiable):
        huge.to_mesh()
    with pytest.raises(MeshUnsatisfiable, match="'tpu'"):
        MeshSpec.of(data=1, device_kind="tpu").to_mesh()  # CPU container


def test_mesh_key_is_content_determined_and_versioned():
    """A live mesh and its spec produce the SAME cache key (fixed-mesh
    and mesh-axis sweeps share score_cache rows), and the key format is
    versioned — it can never collide with the pre-spec hash, which keyed
    a different blob layout."""
    import hashlib
    spec = MeshSpec.of(data=1)
    live = spec.to_mesh()
    assert mesh_key(None) == "local"
    assert mesh_key(LOCAL) == "local"
    assert mesh_key(live) == spec.mid
    assert mesh_key(spec) == spec.mid
    # the pre-MeshSpec (v1) key of the same live mesh
    dev = live.devices.flat[0]
    v1_blob = json.dumps({"axes": list(live.axis_names),
                          "shape": [int(d) for d in live.devices.shape],
                          "platform": str(getattr(dev, "platform", "?"))})
    v1 = hashlib.sha1(v1_blob.encode()).hexdigest()[:12]
    assert mesh_key(live) != v1
    ex = DryRunExecutor(None, timeout_s=60)
    assert env_key(live, ex) == f"{mesh_key(live)}/dryrun:tpu-v5e"


def test_jobspec_carries_meshspec_roundtrip():
    """The satellite wire contract: a JobSpec carrying a MeshSpec (and
    its cache environment column) survives JSON both ways."""
    seg = Segment("g0", "stack", ("attn",), 2)
    combo = Combination("fsdp", frozenset(), SegmentClause())
    spec = JobSpec("k", seg, combo, segments=("m1/kid/g0",), bound_s=1.0,
                   signature="sig", eff_cid="ec",
                   knobs=GlobalKnobs(microbatches=2),
                   mesh=MeshSpec.of(data=2, device_kind="cpu"),
                   mesh_key="abc123/dryrun:tpu-v5e")
    back = JobSpec.from_json(json.loads(json.dumps(spec.to_json())))
    assert back == spec
    assert back.mesh == spec.mesh and back.mesh_key == spec.mesh_key
    # meshless jobs stay meshless (pre-mesh payloads decode unchanged)
    bare = JobSpec("k2", seg, combo)
    wire = json.loads(json.dumps(bare.to_json()))
    assert JobSpec.from_json(wire).mesh is None
    assert JobSpec.from_json(wire).mesh_key == ""


def test_row_cid_mesh_qualified():
    combo = Combination("fsdp", frozenset(), SegmentClause())
    kn = GlobalKnobs(microbatches=2)
    spec = MeshSpec.of(data=2)
    assert row_cid(combo) == combo.cid                     # pre-mesh rows
    assert row_cid(combo, kn) == f"{combo.cid}@{kn.kid}"
    assert row_cid(combo, None, spec) == f"{combo.cid}#{spec.mid}"
    assert row_cid(combo, kn, spec) == f"{combo.cid}@{kn.kid}#{spec.mid}"
    # the swept LOCAL point is qualified too: it must never resume a
    # fixed-mesh row of the same project as its own
    assert row_cid(combo, None, LOCAL) == f"{combo.cid}#local"


# --- worker-rebuilt meshes ---------------------------------------------------


def test_worker_rebuilt_mesh_scores_byte_identical():
    """The satellite contract: a process worker that rebuilds the mesh
    from the JobSpec's MeshSpec scores the program byte-identical to the
    parent scoring under its own locally-built mesh."""
    from repro.core.backends import ProcessBackend

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    spec = MeshSpec.of(data=1)

    parent_cost = DryRunExecutor(None, timeout_s=120).score_segment(
        cfg, shape, seg, combo, mesh=spec.to_mesh())

    backend = ProcessBackend(DryRunExecutor(None, timeout_s=120), cfg,
                             shape, workers=1, timeout_s=120)
    try:
        outs = list(backend.run([JobSpec(
            "j", seg, combo, segments=(seg.name,), mesh=spec)]))
    finally:
        backend.close()
    assert len(outs) == 1 and outs[0].status == "done"
    assert json.dumps(outs[0].cost, sort_keys=True) == \
        json.dumps(parent_cost.as_dict(), sort_keys=True)


def test_unsatisfiable_job_mesh_fails_transient_not_cached():
    """In a worker (past submit validation), a mesh the host cannot
    build is an environment problem, not a verdict on the combination:
    transient, so retryable elsewhere and never cached."""
    from repro.core.backends import ThreadBackend

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    backend = ThreadBackend(DryRunExecutor(None, timeout_s=60), cfg, shape)
    outs = list(backend.run([JobSpec(
        "j", seg, combo, segments=(seg.name,),
        mesh=MeshSpec.of(data=1 << 20))]))
    assert len(outs) == 1
    assert outs[0].status == "failed" and outs[0].transient
    assert "device" in outs[0].error


def test_server_rejects_unsatisfiable_mesh_http_400(tmp_path):
    """The satellite contract: a MeshSpec larger than the server host's
    device count is a protocol error — HTTP 400 at submit, NOT a
    transiently-failing batch the client would retry forever."""
    from repro.configs import arch_to_spec, shape_to_spec
    from repro.core.backends import WIRE_VERSION, executor_to_spec
    from repro.core.backends.server import SweepScoringServer

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    init = {"executor": executor_to_spec(DryRunExecutor(None, timeout_s=60)),
            "arch": arch_to_spec(cfg), "shape": shape_to_spec(shape),
            "shape_key": "sk", "mesh_key": "mk"}

    def post(url, payload):
        req = urllib.request.Request(
            url + "/v1/submit", data=json.dumps(payload).encode(),
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=10) as r:
            return json.loads(r.read())

    srv = SweepScoringServer(str(tmp_path / "srv.db"), workers=1)
    srv.start()
    try:
        # an oversized mesh on a JOB is rejected at submit
        bad_job = JobSpec("j", seg, combo, segments=(seg.name,),
                          mesh=MeshSpec.of(data=1 << 20)).to_json()
        with pytest.raises(urllib.error.HTTPError) as ei:
            post(srv.url, {"v": WIRE_VERSION, "run": "n", "init": init,
                           "jobs": [bad_job]})
        assert ei.value.code == 400
        assert "device" in ei.value.read().decode()
        # an oversized mesh on the INIT EXECUTOR is rejected too
        huge_exec = dict(init["executor"],
                         mesh=MeshSpec.of(data=1 << 20).to_json())
        with pytest.raises(urllib.error.HTTPError) as ei:
            post(srv.url, {"v": WIRE_VERSION, "run": "n",
                           "init": {**init, "executor": huge_exec},
                           "jobs": []})
        assert ei.value.code == 400
        # an env-formatted cache column whose executor-tag half doesn't
        # match the server's rebuilt executor is a protocol error too:
        # scores measured HERE must never be banked as the client's
        # (different) environment
        mismatch = {**init, "mesh_key": "local/wallclock:r5:tpu"}
        with pytest.raises(urllib.error.HTTPError) as ei:
            post(srv.url, {"v": WIRE_VERSION, "run": "n",
                           "init": mismatch, "jobs": []})
        assert ei.value.code == 400
        assert "tag mismatch" in ei.value.read().decode()
        # a satisfiable meshed job is accepted
        ok = JobSpec("j2", seg, combo, segments=(seg.name,),
                     mesh=MeshSpec.of(data=1)).to_json()
        resp = post(srv.url, {"v": WIRE_VERSION, "run": "n", "init": init,
                              "jobs": [ok]})
        assert "batch" in resp
    finally:
        srv.close()


# --- the mesh axis as a swept dimension --------------------------------------


def test_mesh_axis_sweep_registers_per_point_rows_and_chooses_mesh():
    """mesh_space=[local, data1]: one row set per point, the plan's mesh
    chosen by the joint argmin, per-point fused totals reported."""
    tuner = _tuner(SweepDB(":memory:"), "axis")
    plan, rep = _sweep(tuner, mesh_space=[None, {"data": 1}])
    ref_plan, ref_rep = _sweep(_tuner(SweepDB(":memory:"), "ref"))
    assert rep.n_mesh_points == 2
    assert rep.n_combinations == 2 * ref_rep.n_combinations
    assert rep.n_done == rep.n_combinations
    assert plan.mesh is not None
    assert len(rep.per_mesh_total_s) == 2
    assert set(rep.per_mesh_total_s) == {"local", "data1[any]"}
    # the chosen point's total is the min (ties -> earliest point)
    assert plan.meta["predicted_total_s"] == min(rep.per_mesh_total_s.values())
    assert plan.meta["fusion"].endswith("+mesh-argmin")
    # ComPar's guarantee survives the mesh axis: the fused plan beats or
    # equals every single-provider uniform baseline, where baselines are
    # grouped per mesh point (a uniform plan lives on ONE topology)
    baselines = tuner.baselines()
    assert baselines
    assert plan.meta["predicted_total_s"] <= min(baselines.values()) + 1e-12


def test_mesh_axis_matches_fixed_mesh_brute_force():
    """The outer argmin against the brute-force reference: one
    independent FIXED-mesh sweep per point reproduces each point's fused
    total exactly."""
    tuner = _tuner(SweepDB(":memory:"), "swept")
    plan, rep = _sweep(tuner, mesh_space=[None, {"data": 1}])
    ref = {}
    for name, mesh in (("local", None),
                       ("data1[any]", MeshSpec.of(data=1).to_mesh())):
        p, _ = _sweep(_tuner(SweepDB(":memory:"), f"fix-{name}", mesh=mesh))
        ref[name] = p.meta["predicted_total_s"]
    assert rep.per_mesh_total_s == pytest.approx(ref)
    best = min(ref, key=ref.get)
    assert plan.meta["predicted_total_s"] == pytest.approx(ref[best])


def test_mesh_axis_shares_cache_with_fixed_mesh_sweeps(tmp_path):
    """The content-key payoff: a fixed-mesh sweep and a mesh-axis sweep
    of the same topology share score_cache rows — and a repeat mesh-axis
    sweep recompiles NOTHING."""
    db = SweepDB(str(tmp_path / "shared.db"))
    mesh = MeshSpec.of(data=1).to_mesh()
    _, rep_fixed = _sweep(_tuner(db, "fixed", mesh=mesh), use_cache=True)
    assert rep_fixed.n_scored > 0
    # the mesh-axis sweep's data1 point resolves from the fixed sweep's
    # cache rows; only the local point compiles
    _, rep_axis = _sweep(_tuner(db, "axis"), use_cache=True,
                         mesh_space=[None, {"data": 1}])
    local_only = _sweep(_tuner(SweepDB(":memory:"), "loc"))[1].n_scored
    assert rep_axis.n_scored == local_only
    # warm repeat: zero recompiles, identical plan bytes
    plan_a, _ = _sweep(_tuner(db, "axis2"), use_cache=True,
                       mesh_space=[None, {"data": 1}])
    plan_b, rep_warm = _sweep(_tuner(db, "axis3"), use_cache=True,
                              mesh_space=[None, {"data": 1}])
    assert rep_warm.n_scored == 0
    assert rep_warm.n_cached == rep_warm.n_combinations
    assert _plan_bytes(plan_a) == _plan_bytes(plan_b)


def test_mesh_axis_incumbent_scopes_and_pruning_exactness():
    """Pruning with a swept mesh never changes the fused plan: incumbent
    scopes are mesh-qualified, so one topology's best can't prune
    another topology's argmin."""
    from repro.core.backends import Recorder, Scheduler
    from repro.core.tuner import SweepReport

    plan_ref, _ = _sweep(_tuner(SweepDB(":memory:"), "np"),
                         mesh_space=[None, {"data": 1}])
    plan_pr, rep_pr = _sweep(_tuner(SweepDB(":memory:"), "pr"),
                             mesh_space=[None, {"data": 1}],
                             prune=True, prune_margin=0.0, workers=2)
    assert _plan_bytes(plan_pr) == _plan_bytes(plan_ref)
    assert (rep_pr.n_done + rep_pr.n_failed + rep_pr.n_pruned
            == rep_pr.n_combinations)

    # scheduler-level: swept jobs carry mesh-qualified scopes + per-point
    # cache environment columns
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    db = SweepDB(":memory:")
    db.open_project("s", "new")
    ex = DryRunExecutor(None, timeout_s=60)
    sched = Scheduler(db, "s", cfg, shape, None, ex)
    segs = fragment(cfg)
    combos = {s.name: [Combination("fsdp", frozenset(), SegmentClause())]
              for s in segs}
    db.register_many("s", [(s.name, combos[s.name][0], None, mp)
                           for s in segs
                           for mp in (LOCAL, MeshSpec.of(data=1))])
    rec = Recorder(db, "s", SweepReport("s", 0))
    work = sched.build(segs, combos, rec,
                       mesh_points=[LOCAL, MeshSpec.of(data=1)])
    mid = MeshSpec.of(data=1).mid
    scopes = {s for j in work.jobs for s in j.segments}
    assert any(s.startswith("local/") for s in scopes)
    assert any(s.startswith(f"{mid}/") for s in scopes)
    envs = {j.mesh_key for j in work.jobs}
    assert envs == {f"local/{ex.cache_tag}", f"{mid}/{ex.cache_tag}"}


@pytest.mark.skipif(N_DEV < 2, reason=f"needs >=2 devices, have {N_DEV}")
def test_mesh_axis_multidevice_resharding_differentiates_boundary_costs():
    """On a real multi-device point the Viterbi boundary costs are
    mesh-dependent: the per-mesh fused totals under boundary_costs are
    computed per point (and the local point charges zero)."""
    tuner = _tuner(SweepDB(":memory:"), "bc")
    plan, rep = _sweep(tuner, mesh_space=[None, {"data": 2}],
                       boundary_costs=True)
    assert set(rep.per_mesh_total_s) == {"local", "data2[any]"}
    assert plan.meta["fusion"].startswith("viterbi-boundary") or \
        plan.meta["fusion"].startswith("per-segment-argmin")
    assert plan.meta["fusion"].endswith("+mesh-argmin")


# --- the acceptance invariant ------------------------------------------------


@pytest.mark.skipif(N_DEV < 2, reason=f"needs >=2 devices, have {N_DEV}")
def test_mesh_axis_backend_equivalence_and_warm_cache(tmp_path):
    """The acceptance criterion: a >=2-point mesh_space sweep fuses
    byte-identical plans (segments, knobs AND chosen mesh) on the
    sequential, process and remote backends; a repeat sweep against the
    same cache recompiles nothing."""
    from repro.core.backends.server import SweepScoringServer

    space = [{"data": 1}, {"data": 2}]
    plan_ref, rep_ref = _sweep(_tuner(SweepDB(":memory:"), "eq-seq"),
                               backend="sequential", mesh_space=space)
    assert plan_ref.mesh is not None and rep_ref.n_failed == 0
    ref = _plan_bytes(plan_ref)

    t_p = _tuner(SweepDB(str(tmp_path / "proc.db")), "eq-prc")
    try:
        plan_p, rep_p = _sweep(t_p, backend="process", workers=2,
                               mesh_space=space, use_cache=True)
        assert _plan_bytes(plan_p) == ref
        assert rep_p.n_scored == rep_ref.n_scored
        # repeat on the same DB: zero recompiles, same bytes
        plan_w, rep_w = _sweep(_tuner(SweepDB(str(tmp_path / "proc.db")),
                                      "eq-prc-warm"),
                               backend="process", workers=2,
                               mesh_space=space, use_cache=True)
        assert _plan_bytes(plan_w) == ref
        assert rep_w.n_scored == 0
        assert rep_w.n_cached == rep_w.n_combinations
    finally:
        t_p.close()

    srv = SweepScoringServer(str(tmp_path / "server.db"), workers=2)
    srv.start()
    try:
        plan_r, rep_r = _sweep(_tuner(SweepDB(":memory:"), "eq-rem"),
                               remote_url=srv.url, mesh_space=space)
        assert _plan_bytes(plan_r) == ref
        assert rep_r.n_failed == 0
        # a second client is served entirely from the server's cache
        plan_r2, rep_r2 = _sweep(_tuner(SweepDB(":memory:"), "eq-rem2"),
                                 remote_url=srv.url, mesh_space=space)
        assert _plan_bytes(plan_r2) == ref
        assert rep_r2.n_scored == 0
    finally:
        srv.close()
