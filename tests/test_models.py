"""Per-architecture smoke tests (reduced config, CPU): one forward +
one train step + one decode step, asserting shapes and no NaNs — plus
model-level equivalence properties (chunked==naive attention, decode
consistency with teacher forcing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, get_shape
from repro.core.plan import uniform_plan
from repro.models import (ModelContext, SegmentClause, forward, init_cache,
                          init_params, model_specs, decode_step)
from repro.models.attention import chunked_attention, naive_attention
from repro.train.step import init_train_state, jit_train_step

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, B=2, S=32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    batch = {"targets": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["embeds"] = (jax.random.normal(ks[1], (B, S, cfg.d_model))
                           * 0.02).astype(cfg.dtype)
    else:
        batch["tokens"] = jax.random.randint(ks[2], (B, S), 0,
                                             cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward(arch):
    cfg = get_arch(arch).smoke()
    params = init_params(model_specs(cfg), jax.random.key(0))
    batch = make_batch(cfg)
    logits, aux = forward(params, batch, cfg, ModelContext())
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    if cfg.is_moe:
        assert float(aux) > 0.0    # load-balance loss active


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_arch(arch).smoke()
    plan = uniform_plan(cfg, "fsdp", clause=SegmentClause(remat="dots"))
    step, _ = jit_train_step(cfg, None, plan)
    params, opt = init_train_state(cfg, plan, jax.random.key(0))
    batch = make_batch(cfg, B=2, S=16)
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["total_loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = get_arch(arch).smoke()
    params = init_params(model_specs(cfg), jax.random.key(0))
    B, S = 2, 32
    caches = init_cache(cfg, B, S)
    tok = jnp.zeros((B,), jnp.int32)
    logits, caches = decode_step(params, caches, tok, jnp.int32(0), cfg,
                                 ModelContext())
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


# --- decode == teacher-forced forward (the cache-correctness property) ------

@pytest.mark.parametrize("arch", [
    "granite-8b",            # GQA full attention
    "starcoder2-3b",         # sliding window (ring buffer)
    "recurrentgemma-2b",     # RG-LRU + local attention hybrid
    "xlstm-125m",            # mLSTM + sLSTM recurrent
    "chatglm3-6b",           # 2d RoPE
])
def test_decode_matches_forward(arch):
    cfg = get_arch(arch).smoke()
    params = init_params(model_specs(cfg), jax.random.key(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                cfg.vocab_size)
    ctx = ModelContext()
    full_logits, _ = forward(params, {"tokens": tokens}, cfg, ctx)
    caches = init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg, ctx))
    errs = []
    for t in range(S):
        logits, caches = step(params, caches, tokens[:, t], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(
            logits - full_logits[:, t]))))
    assert max(errs) < 2e-2, f"decode diverges from forward: {errs}"


def test_chunked_equals_naive_attention():
    B, S, H, KV, D = 2, 128, 4, 2, 16
    q = jax.random.normal(jax.random.key(1), (B, S, H, D))
    k = jax.random.normal(jax.random.key(2), (B, S, KV, D))
    v = jax.random.normal(jax.random.key(3), (B, S, KV, D))
    pos = jnp.arange(S)
    for window in (0, 32):
        a = naive_attention(q, k, v, pos_q=pos, pos_k=pos, window=window)
        b = chunked_attention(q, k, v, pos_q=pos, pos_k=pos, window=window,
                              q_chunk=32)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_pallas_plan_matches_xla_plan():
    """Black-box equivalence of the kernel clause (what the validator
    guarantees for every swept combination)."""
    cfg = get_arch("recurrentgemma-2b").smoke()
    params = init_params(model_specs(cfg), jax.random.key(0))
    batch = make_batch(cfg)
    lx, _ = forward(params, batch, cfg,
                    ModelContext(clause=SegmentClause(kernel="xla")))
    lp, _ = forward(params, batch, cfg,
                    ModelContext(clause=SegmentClause(
                        kernel="pallas", mlstm_chunk=16, block_q=16,
                        block_k=16)))
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               atol=5e-3, rtol=5e-3)


def test_param_counts_match_nominal():
    """Param counts stay faithful to the assigned configs."""
    from repro.models.params import param_count
    expect = {
        "xlstm-125m": (0.10e9, 0.2e9),
        "stablelm-3b": (2.5e9, 3.2e9),
        "granite-8b": (7.5e9, 8.6e9),
        "chatglm3-6b": (5.8e9, 6.6e9),
        "starcoder2-3b": (2.8e9, 3.3e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
        "recurrentgemma-2b": (2.5e9, 3.1e9),
    }
    for name, (lo, hi) in expect.items():
        n = param_count(model_specs(get_arch(name)))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo},{hi}]"
