"""Beyond-paper optimization clauses must be numerics-preserving (the
black-box-validation property, applied to each §Perf mechanism)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.mesh import make_test_mesh
from repro.models import attention as A
from repro.models.context import ModelContext, SegmentClause
from repro.models.moe import moe_apply, moe_specs
from repro.models.params import init_params
from repro.runtime.sharding import Rules


@pytest.fixture(scope="module")
def mesh11():
    return make_test_mesh(1, 1)


def test_a2a_moe_matches_sorted(mesh11):
    cfg = get_arch("qwen3-moe-30b-a3b").smoke()
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    rules = Rules({"batch": "data", "experts": "model"}, mesh11)
    y1, a1 = moe_apply(p, x, cfg, ModelContext(
        rules=rules, clause=SegmentClause(moe_dispatch="sorted")))
    y2, a2 = moe_apply(p, x, cfg, ModelContext(
        rules=rules, clause=SegmentClause(moe_dispatch="a2a")))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-3, rtol=5e-2)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_a2a_moe_grads_flow(mesh11):
    cfg = get_arch("qwen3-moe-30b-a3b").smoke()
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.5
    rules = Rules({"batch": "data", "experts": "model"}, mesh11)
    ctx = ModelContext(rules=rules,
                       clause=SegmentClause(moe_dispatch="a2a"))
    g = jax.grad(lambda p: moe_apply(p, x, cfg, ctx)[0].sum())(p)
    for leaf in jax.tree.leaves(g):
        assert not np.any(np.isnan(np.asarray(leaf, np.float32)))


def test_shardmap_decode_matches_pjit(mesh11):
    cfg = get_arch("granite-8b").smoke()
    p = init_params(A.attn_specs(cfg), jax.random.key(0))
    rules = Rules({"batch": "data", "kv_seq": "model", "kv_heads": None},
                  mesh11)
    B, S = 2, 32
    zero = {"k": jnp.zeros((B, S, cfg.num_kv_heads, cfg.head_dim_)),
            "v": jnp.zeros((B, S, cfg.num_kv_heads, cfg.head_dim_))}
    x = jax.random.normal(jax.random.key(1), (B, cfg.d_model)) * 0.3
    ctx0 = ModelContext(rules=rules, clause=SegmentClause())
    ctx1 = ModelContext(rules=rules,
                        clause=SegmentClause(decode_shardmap=True))
    c0, c1 = dict(zero), dict(zero)
    for pos in range(6):
        y0, c0 = A.attn_decode(p, x, c0, jnp.int32(pos), cfg, ctx0)
        y1, c1 = A.attn_decode(p, x, c1, jnp.int32(pos), cfg, ctx1)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(c0["k"]),
                                   np.asarray(c1["k"]), atol=1e-5)


@pytest.mark.parametrize("pos", [0, 17, 63])
def test_bf16_cache_read_matches_upcast(pos):
    q = jax.random.normal(jax.random.key(2), (2, 4, 16), jnp.bfloat16)
    kc = jax.random.normal(jax.random.key(3), (2, 64, 2, 16), jnp.bfloat16)
    vc = jax.random.normal(jax.random.key(4), (2, 64, 2, 16), jnp.bfloat16)
    o1 = A.decode_attention(q, kc, vc, pos, upcast=True)
    o2 = A.decode_attention(q, kc, vc, pos, upcast=False)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_windowed_chunked_attention_no_full_copies():
    """window >= Sk must take the no-slice path and stay exact."""
    B, S, H, KV, D = 1, 128, 2, 1, 16
    q = jax.random.normal(jax.random.key(1), (B, S, H, D))
    k = jax.random.normal(jax.random.key(2), (B, S, KV, D))
    v = jax.random.normal(jax.random.key(3), (B, S, KV, D))
    pos = jnp.arange(S)
    a = A.naive_attention(q, k, v, pos_q=pos, pos_k=pos, window=S + 64)
    b = A.chunked_attention(q, k, v, pos_q=pos, pos_k=pos, window=S + 64,
                            q_chunk=32)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
