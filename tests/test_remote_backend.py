"""The remote scoring backend + sweep scoring server (sweep-as-a-service).

Acceptance invariants: sequential, thread, process and remote (loopback
server) backends fuse byte-identical plans on the same sweep; a second
remote sweep against a warm server cache performs ZERO server-side
compiles; submits are idempotent (content-keyed batches); a vanished
batch is recovered by resubmission; an unreachable server fails jobs as
*transient* — and transient outcomes never enter the score cache or mark
an incumbent, across all four backends.
"""
import json
import socket
import time
import urllib.error
import urllib.request

import pytest

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.backends import (JobGroup, JobSpec, Recorder, RemoteBackend,
                                 ThreadBackend, WIRE_VERSION, make_backend)
from repro.core.backends.server import SweepScoringServer, batch_id
from repro.core.combinator import Combination
from repro.core.executor import (CombinationFailed, CrashExecutor,
                                 DryRunExecutor)
from repro.core.segment import fragment
from repro.core.tuner import SweepReport
from repro.models.context import SegmentClause

SPACE = {"remat": ("none", "full"), "kernel": ("xla",), "block_q": (16, 32),
         "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}


def _plan_bytes(plan):
    d = plan.to_json()
    return json.dumps({"segments": d["segments"], "knobs": d["knobs"]},
                      sort_keys=True).encode()


def _tuner(db, project, mode="new"):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return ComParTuner(cfg, shape, mesh=None, db=db, project=project,
                       mode=mode, executor="dryrun", timeout_s=120)


def _sweep(tuner, **kw):
    return tuner.sweep(providers=["tensor_par", "fsdp"], clause_space=SPACE,
                       max_flags=1, use_cache=False, **kw)


def _stats(url):
    with urllib.request.urlopen(url + "/v1/stats", timeout=10) as r:
        return json.loads(r.read())


def _dead_url():
    """A URL nothing listens on (bind a port, then release it)."""
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return f"http://127.0.0.1:{port}"


@pytest.fixture
def server(tmp_path):
    srv = SweepScoringServer(str(tmp_path / "server.db"), workers=2)
    srv.start()
    yield srv
    srv.close()


# --- the acceptance invariant ------------------------------------------------


def test_backend_equivalence_includes_remote_and_warm_server(server):
    """sequential == thread == process == remote (loopback server), and a
    second remote sweep against the warm server cache compiles NOTHING
    server-side."""
    plan_ref, rep_ref = _sweep(_tuner(SweepDB(":memory:"), "eq-seq"),
                               backend="sequential")
    ref = _plan_bytes(plan_ref)

    plan_t, rep_t = _sweep(_tuner(SweepDB(":memory:"), "eq-thr"),
                           backend="thread", workers=2)
    assert _plan_bytes(plan_t) == ref

    t_p = _tuner(SweepDB(":memory:"), "eq-prc")
    try:
        plan_p, rep_p = _sweep(t_p, backend="process", workers=2)
    finally:
        t_p.close()
    assert _plan_bytes(plan_p) == ref

    plan_r, rep_r = _sweep(_tuner(SweepDB(":memory:"), "eq-rem"),
                           backend="remote", remote_url=server.url)
    assert _plan_bytes(plan_r) == ref
    assert (rep_r.n_done, rep_r.n_failed, rep_r.n_scored, rep_r.n_shared) \
        == (rep_ref.n_done, 0, rep_ref.n_scored, rep_ref.n_shared)
    cold = _stats(server.url)
    assert cold["n_compiled"] == rep_ref.n_scored > 0

    # cross-host amortization: a fresh client (empty local DB) is served
    # everything from the server's score cache — zero new compiles
    plan_w, rep_w = _sweep(_tuner(SweepDB(":memory:"), "eq-rem-warm"),
                           remote_url=server.url)     # url implies remote
    assert _plan_bytes(plan_w) == ref
    assert rep_w.n_scored == 0
    assert rep_w.n_cached == rep_w.n_combinations
    warm = _stats(server.url)
    assert warm["n_compiled"] == cold["n_compiled"], \
        "warm remote sweep compiled server-side"
    assert warm["n_cache_hits"] > cold["n_cache_hits"]


# --- protocol contracts ------------------------------------------------------


def _dry_init():
    from repro.configs import arch_to_spec, shape_to_spec
    from repro.core.backends import executor_to_spec
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return {"executor": executor_to_spec(DryRunExecutor(None, timeout_s=60)),
            "arch": arch_to_spec(cfg), "shape": shape_to_spec(shape),
            "shape_key": "sk", "mesh_key": "mk"}


def _post(url, payload):
    req = urllib.request.Request(
        url + "/v1/submit", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=10) as r:
        return json.loads(r.read())


def test_submit_is_idempotent_content_keyed(server):
    payload = {"v": WIRE_VERSION, "run": "fixed-nonce", "init": _dry_init(),
               "jobs": []}
    a = _post(server.url, payload)
    b = _post(server.url, payload)
    assert a["batch"] == b["batch"] == batch_id(payload)
    assert not a["resumed"] and b["resumed"]
    assert _stats(server.url)["n_batches"] == 1
    # a different run nonce is a different batch
    c = _post(server.url, {**payload, "run": "other-nonce"})
    assert c["batch"] != a["batch"] and not c["resumed"]


def test_wire_version_mismatch_rejected(server):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    backend = RemoteBackend(DryRunExecutor(None), cfg, shape,
                            url=server.url, retry_s=1.0)
    with pytest.raises(RuntimeError, match="HTTP 400"):
        backend._request("/v1/submit", {"v": 99, "init": _dry_init(),
                                        "jobs": []})


def test_server_rejects_test_executor_specs_from_the_wire(tmp_path, server):
    """``{"kind": "crash"}`` from an untrusted client would be a remote
    kill switch for every worker — rejected at submit unless the server
    opted in with --allow-test-executors."""
    bad = {"v": WIRE_VERSION, "run": "n1",
           "init": {**_dry_init(), "executor": {"kind": "crash"}},
           "jobs": []}
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(server.url, bad)
    assert ei.value.code == 400
    trusting = SweepScoringServer(str(tmp_path / "trusting.db"),
                                  workers=1, allow_test=True)
    trusting.start()
    try:
        assert "batch" in _post(trusting.url, bad)   # empty batch: no spawn
    finally:
        trusting.close()


def test_submit_404_raises_not_transient(server):
    """A 404 on /v1/submit means the URL is not a scoring server (wrong
    path, wrong service) — a protocol error that must raise, never a
    sweep full of silent transient failures."""
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    backend = RemoteBackend(DryRunExecutor(None), cfg, shape,
                            url=server.url + "/api", retry_s=1.0)
    with pytest.raises(RuntimeError, match="HTTP 404"):
        list(backend.run([JobSpec("j", seg, combo, segments=(seg.name,))]))


def test_submit_validates_specs_eagerly(server):
    """Deterministic payload errors (registry skew, malformed JobSpec)
    are HTTP 400 at submit — not a batch that 'transiently' fails on
    every retry forever."""
    good = _dry_init()
    bad_arch = {"v": WIRE_VERSION, "run": "n", "jobs": [],
                "init": {**good, "arch": {"name": "no-such-arch"}}}
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(server.url, bad_arch)
    assert ei.value.code == 400
    bad_job = {"v": WIRE_VERSION, "run": "n", "init": good,
               "jobs": [{"key": "k"}]}          # no seg/combo
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(server.url, bad_job)
    assert ei.value.code == 400


def test_backend_remote_requires_url():
    with pytest.raises(ValueError, match="remote_url"):
        _sweep(_tuner(SweepDB(":memory:"), "nourl"), backend="remote")
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    with pytest.raises(ValueError, match="remote_url"):
        make_backend("remote", DryRunExecutor(None), cfg, shape)


def test_vanished_batch_is_resubmitted_and_served_from_cache(server):
    """The idempotent-recovery path: the server forgets a batch (restart/
    eviction) mid-poll — the client resubmits its content-keyed payload
    and the replacement batch resolves from the score cache."""
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    job = JobSpec("sig/ec", seg, combo, segments=(seg.name,),
                  signature="sig", eff_cid="ec")

    backend = RemoteBackend(DryRunExecutor(None, timeout_s=120), cfg, shape,
                            url=server.url, shape_key="sk", mesh_key="mk",
                            poll_s=0.2, retry_s=10.0)
    submits = []
    orig_submit = backend._submit

    def evicting_submit(payload):
        bid = orig_submit(payload)
        submits.append(bid)
        if len(submits) == 1 and bid is not None:
            batch = server.batch(bid)
            deadline = time.monotonic() + 120
            while not batch.done and time.monotonic() < deadline:
                time.sleep(0.02)
            assert batch.done, "first batch never finished server-side"
            with server._lock:
                del server._batches[bid]
        return bid

    backend._submit = evicting_submit
    outs = list(backend.run([job]))
    assert len(submits) == 2 and submits[0] == submits[1]  # content-keyed
    assert [o.status for o in outs] == ["done"]
    assert outs[0].cached        # the resubmitted batch hit the cache
    assert _stats(server.url)["n_compiled"] == 1


def test_unreachable_server_fails_jobs_transient():
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    backend = RemoteBackend(DryRunExecutor(None), cfg, shape,
                            url=_dead_url(), retry_s=0.3, backoff_s=0.05)
    outs = list(backend.run([
        JobSpec("a", seg, combo, segments=(seg.name,)),
        JobSpec("b", seg, combo, segments=(seg.name,))]))
    assert len(outs) == 2
    assert all(o.status == "failed" and o.transient for o in outs)
    assert all("unreachable" in o.error for o in outs)


# --- the transient cache policy, end-to-end across all four backends ---------


class _TransientExecutor:
    """Raises a transient CombinationFailed for every job (the in-process
    stand-in for a deadline overrun)."""
    parallel_safe = True
    timeout_s = None
    cache_tag = "transient-test"
    n_chips = 1

    def score_segment(self, cfg, shape, seg, combo, knobs=None):
        raise CombinationFailed("synthetic deadline overrun", transient=True)


def _drive_policy(backend, jobs, db, tracker):
    """Run jobs through a backend + Recorder and assert the transient
    policy: every outcome failed+transient, nothing cached, no incumbent
    marked."""
    groups = {}
    for job in jobs:
        db.register("p", job.seg.name, job.combo)
        groups[job.key] = JobGroup(
            job.seg, job.combo, job.signature, job.eff_cid,
            members=[(job.seg.name, job.combo.cid)])
    rep = SweepReport("p", n_combinations=len(jobs))
    rec = Recorder(db, "p", rep, shape_key="sk", mesh_key="mk",
                   use_cache=True)
    outs = []
    for out in backend.run(jobs):
        outs.append(out)
        rec.outcome(groups[out.key], out)
    rec.flush()
    assert len(outs) == len(jobs)
    assert all(o.status == "failed" and o.transient for o in outs)
    assert rep.n_transient == len(jobs)
    assert db.cache_size() == 0, "transient outcome leaked into score_cache"
    assert tracker._best == {}, "transient outcome marked an incumbent"
    assert all(r["status"] == "failed" for r in db.results("p"))


@pytest.mark.parametrize("backend_name", ["sequential", "thread", "process",
                                          "remote"])
def test_transient_outcomes_never_cached_never_incumbent(backend_name,
                                                         tmp_path):
    """The satellite contract, per backend: transient failures (deadline
    overrun, worker crash double-loss, remote connection loss) are
    recorded as failed rows but never enter ``score_cache`` and never
    tighten an incumbent."""
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    jobs = []
    for i, provider in enumerate(("fsdp", "tensor_par")):
        combo = Combination(provider, frozenset(), SegmentClause())
        jobs.append(JobSpec(f"sig{i}/ec", seg, combo, segments=(seg.name,),
                            signature=f"sig{i}", eff_cid="ec"))
    db = SweepDB(str(tmp_path / f"{backend_name}.db"))
    db.open_project("p", "new")

    if backend_name in ("sequential", "thread"):
        backend = ThreadBackend(_TransientExecutor(), cfg, shape,
                                workers=1 if backend_name == "sequential"
                                else 2)
        tracker = backend.runner.tracker
    elif backend_name == "process":
        from repro.core.backends import ProcessBackend
        backend = ProcessBackend(CrashExecutor(), cfg, shape, workers=1,
                                 timeout_s=60)
        tracker = backend.tracker
    else:
        backend = RemoteBackend(DryRunExecutor(None), cfg, shape,
                                url=_dead_url(), retry_s=0.3,
                                backoff_s=0.05)
        tracker = backend.tracker
    try:
        _drive_policy(backend, jobs, db, tracker)
        if backend_name in ("process", "remote"):
            # these backends rebuild their tracker per run()
            assert backend.tracker._best == {}
    finally:
        backend.close()


# --- shared-secret auth + batch TTL eviction ---------------------------------


def test_token_auth_required_and_never_retried(tmp_path):
    """A --token server 401s requests without (or with the wrong) bearer
    token; the client treats 401 as a protocol error — raised at once,
    with zero retry-budget burned (a wrong token stays wrong)."""
    from repro.core.backends import RetryPolicy
    srv = SweepScoringServer(str(tmp_path / "auth.db"), workers=1,
                             token="s3cret")
    srv.start()
    try:
        cfg = get_arch("granite-8b").smoke()
        shape = get_shape("train_4k").smoke()

        def client(token):
            return RemoteBackend(DryRunExecutor(None), cfg, shape,
                                 url=srv.url, token=token,
                                 retry=RetryPolicy(budget_s=30.0,
                                                   base_s=1.0))
        for bad in (None, "wrong"):
            t0 = time.monotonic()
            with pytest.raises(RuntimeError, match="HTTP 401"):
                client(bad)._request("/v1/health", timeout=5.0)
            assert time.monotonic() - t0 < 5.0, "401 burned the retry budget"
        assert client("s3cret")._request("/v1/health",
                                         timeout=5.0)["ok"] is True
    finally:
        srv.close()


def test_token_auth_sweep_end_to_end(tmp_path):
    """remote_token= flows tuner -> make_backend -> Authorization header;
    the authed sweep matches the open-server plan byte-for-byte."""
    ref, _ = _sweep(_tuner(SweepDB(":memory:"), "auth-ref"),
                    backend="sequential")
    srv = SweepScoringServer(str(tmp_path / "auth-e2e.db"), workers=2,
                             token="s3cret")
    srv.start()
    try:
        plan, rep = _sweep(_tuner(SweepDB(":memory:"), "auth-e2e"),
                           remote_url=srv.url, remote_token="s3cret")
        assert _plan_bytes(plan) == _plan_bytes(ref)
        assert rep.n_failed == 0
    finally:
        srv.close()


def test_non_loopback_bind_refused_without_token(tmp_path):
    """An open scoring server on a routable interface is a free compile
    farm + writable score cache: refused at construction, allowed with a
    token (and loopback stays tokenless-friendly)."""
    with pytest.raises(ValueError, match="token"):
        SweepScoringServer(str(tmp_path / "open.db"), host="0.0.0.0")
    srv = SweepScoringServer(str(tmp_path / "tok.db"), host="127.0.0.1")
    srv.close()     # loopback without token: fine (never started)


def test_finished_batches_ttl_evicted(tmp_path):
    """Completed batches are TTL-swept (counted in /v1/stats); an
    evicted batch polls as 404, which the client already recovers from
    by resubmitting."""
    srv = SweepScoringServer(str(tmp_path / "ttl.db"), workers=1,
                             batch_ttl_s=0.05)
    srv.start()
    try:
        payload = {"v": WIRE_VERSION, "run": "ttl-nonce",
                   "init": _dry_init(), "jobs": []}
        bid = _post(srv.url, payload)["batch"]
        # empty batch: finishes immediately — wait for done via the poll
        with urllib.request.urlopen(
                srv.url + f"/v1/outcomes?batch={bid}&after=0&wait=10",
                timeout=30) as r:
            assert json.loads(r.read())["done"]
        time.sleep(0.1)                       # let the TTL lapse
        stats = _stats(srv.url)               # stats sweeps eviction
        assert stats["n_evicted"] >= 1
        assert stats["n_batches"] == 0
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(
                srv.url + f"/v1/outcomes?batch={bid}&after=0&wait=0",
                timeout=10)
        assert ei.value.code == 404
    finally:
        srv.close()
