"""Serving subsystem: PlanRegistry persistence + the continuous-batching
engine's byte-identity contract (batched streams == sequential streams).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.db import SweepDB
from repro.core.meshspec import MeshSpec
from repro.core.plan import uniform_plan
from repro.models.context import SegmentClause
from repro.serve import (PlanRegistry, Request, ServeEngine, make_prefill,
                         serving_shape)
from repro.serve.engine import cache_batch_axes


def _cfg(name="stablelm-3b"):
    return get_arch(name).smoke()


def _plan(cfg, **kw):
    clause = SegmentClause(remat="none", kernel="xla", **kw)
    return uniform_plan(cfg, "tensor_par", set(), clause)


def _reqs(cfg, n, *, seed=0, tokens=6, prompt_len=3):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        p = max(1, prompt_len + int(rng.randint(-1, 2)))
        out.append(Request(
            rid=f"r{i}",
            prompt=tuple(int(t) for t in rng.randint(0, cfg.vocab_size, p)),
            max_new_tokens=tokens + i % 3))
    return out


# --- registry ---------------------------------------------------------------

def test_registry_roundtrip_byte_identical_plan(tmp_path):
    cfg = _cfg()
    plan = _plan(cfg)
    plan.meta["predicted_total_s"] = 1.25e-4
    reg = PlanRegistry(str(tmp_path / "reg.db"))
    shape = serving_shape(4, 64)
    reg.register(cfg, shape, plan, report={"note": "t"}, cache_tag="dry")
    e = reg.lookup(cfg, shape, cache_tag="dry")
    assert e is not None and e.exact
    assert json.dumps(e.plan.to_json(), sort_keys=True) == \
        json.dumps(plan.to_json(), sort_keys=True)
    assert e.total_s == pytest.approx(1.25e-4)
    assert e.report == {"note": "t"}
    assert e.kind == "decode" and (e.seq_len, e.batch) == (64, 4)


def test_registry_mesh_mismatch_is_a_miss(tmp_path):
    cfg = _cfg()
    reg = PlanRegistry(str(tmp_path / "reg.db"))
    shape = serving_shape(4, 64)
    reg.register(cfg, shape, _plan(cfg), mesh=MeshSpec.of(data=2))
    # meshless lookup must not see the data=2 plan, nearest or not
    assert reg.lookup(cfg, shape) is None
    assert reg.lookup(cfg, serving_shape(4, 128)) is None
    # ... and the right mesh resolves it
    e = reg.lookup(cfg, shape, MeshSpec.of(data=2))
    assert e is not None and e.exact and e.mesh_mid != "local"


def test_registry_nearest_shape_fallback_deterministic(tmp_path):
    cfg = _cfg()
    reg = PlanRegistry(str(tmp_path / "reg.db"))
    reg.register(cfg, serving_shape(4, 64), _plan(cfg))
    reg.register(cfg, serving_shape(4, 256), _plan(cfg, cache_upcast=False))
    # 96 is log2-closer to 64 (0.58) than to 256 (1.41)
    e = reg.lookup(cfg, serving_shape(4, 96))
    assert e is not None and not e.exact and e.seq_len == 64
    # exact tie (64 between 32 and 128): sort-order tie-break, stable
    reg2 = PlanRegistry(str(tmp_path / "reg2.db"))
    reg2.register(cfg, serving_shape(4, 32), _plan(cfg))
    reg2.register(cfg, serving_shape(4, 128), _plan(cfg))
    picks = {reg2.lookup(cfg, serving_shape(4, 64)).shape
             for _ in range(5)}
    assert picks == {"decode:128x4"}
    # nearest=False: the fallback is opt-out
    assert reg.lookup(cfg, serving_shape(4, 96), nearest=False) is None


def test_registry_reregister_newest_wins(tmp_path):
    cfg = _cfg()
    reg = PlanRegistry(str(tmp_path / "reg.db"))
    shape = serving_shape(4, 64)
    reg.register(cfg, shape, _plan(cfg, cache_upcast=True))
    first = reg.lookup(cfg, shape).plan.to_json()
    reg.register(cfg, shape, _plan(cfg, cache_upcast=False))
    second = reg.lookup(cfg, shape).plan.to_json()
    assert first != second
    assert len(reg.entries(cfg.name)) == 1


def test_registry_shares_db_file_with_score_cache(tmp_path):
    path = str(tmp_path / "both.db")
    db = SweepDB(path)
    reg = PlanRegistry(db)
    cfg = _cfg()
    reg.register(cfg, serving_shape(2, 32), _plan(cfg))
    # a second handle on the same file sees the plan (WAL persistence)
    assert PlanRegistry(path).lookup(cfg, serving_shape(2, 32)) is not None


def test_tuner_registers_fused_plan(tmp_path):
    from repro.core.tuner import ComParTuner
    cfg = _cfg()
    shape = serving_shape(2, 32)
    db = SweepDB(str(tmp_path / "sweep.db"))
    tuner = ComParTuner(cfg, shape, db=db, project="reg-e2e",
                        executor="dryrun", registry=True)
    with tuner:
        plan, rep = tuner.sweep(
            providers=("tensor_par",),
            clause_space={"remat": ("none",), "kernel": ("xla",),
                          "cache_upcast": (True, False)},
            max_flags=0, backend="sequential")
    e = tuner.registry.lookup(cfg, shape,
                              cache_tag=tuner.executor.cache_tag)
    assert e is not None and e.exact
    assert json.dumps(e.plan.to_json(), sort_keys=True) == \
        json.dumps(plan.to_json(), sort_keys=True)
    assert e.total_s == pytest.approx(plan.meta["predicted_total_s"])
    assert "summary" in e.report
    # acceptance: overlapping requests under the REGISTERED plan stream
    # byte-identically to sequential decoding under the same plan
    eng = ServeEngine(cfg, e.plan, capacity=e.batch, cache_len=e.seq_len)
    reqs = _reqs(cfg, 4, tokens=4, prompt_len=2)
    batched, seq = eng.run(reqs), eng.run(reqs, max_active=1)
    assert all(batched[r.rid].tokens == seq[r.rid].tokens for r in reqs)


# --- engine -----------------------------------------------------------------

def test_engine_batched_equals_sequential_byte_identical():
    """The tentpole contract: >=3 overlapping requests, every stream
    byte-identical to the one-request-at-a-time loop on the same plan."""
    cfg = _cfg()
    eng = ServeEngine(cfg, _plan(cfg), capacity=4, cache_len=32)
    reqs = _reqs(cfg, 7)
    batched = eng.run(reqs)
    assert eng.stats.peak_active >= 3
    assert eng.stats.n_completed == len(reqs)
    sequential = eng.run(reqs, max_active=1)
    assert eng.stats.peak_active == 1
    for r in reqs:
        assert batched[r.rid].tokens == sequential[r.rid].tokens, r.rid
        assert batched[r.rid].finish_reason == \
            sequential[r.rid].finish_reason


def test_engine_streams_independent_of_batch_mates():
    """A request's stream must not change with WHO it shares slots with."""
    cfg = _cfg()
    eng = ServeEngine(cfg, _plan(cfg), capacity=3, cache_len=32)
    probe = Request(rid="p", prompt=(5, 9, 2), max_new_tokens=8)
    alone = eng.run([probe])["p"].tokens
    crowd = _reqs(cfg, 5, seed=7)
    mixed = eng.run([probe] + crowd)["p"].tokens
    assert mixed == alone


def test_engine_eos_recycles_slot():
    cfg = _cfg()
    eng = ServeEngine(cfg, _plan(cfg), capacity=2, cache_len=32)
    probe = Request(rid="p", prompt=(1, 2, 3), max_new_tokens=20)
    ref = eng.run([probe])["p"].tokens
    # cut the stream at a token whose value does not occur earlier, so
    # the EOS fires at exactly that index whatever the stream contents
    k = next(i for i in range(1, len(ref)) if ref[i] not in ref[:i])
    done = eng.run([Request(rid="p", prompt=(1, 2, 3), max_new_tokens=20,
                            eos_id=ref[k]),
                    Request(rid="q", prompt=(4, 4), max_new_tokens=12)])
    assert done["p"].finish_reason == "eos"
    assert done["p"].tokens == ref[:k + 1]
    assert done["q"].finish_reason == "length"
    # the freed slot was reusable: both fit capacity 2 regardless, but
    # the EOS'd request must have finished earlier than q
    assert done["p"].done_step <= done["q"].done_step


def test_engine_overflow_and_duplicate_rid_rejected():
    cfg = _cfg()
    eng = ServeEngine(cfg, _plan(cfg), capacity=2, cache_len=8)
    with pytest.raises(ValueError, match="cache_len"):
        eng.run([Request(rid="a", prompt=(1, 2, 3, 4), max_new_tokens=8)])
    with pytest.raises(ValueError, match="duplicate"):
        eng.run([Request(rid="a", prompt=(1,), max_new_tokens=2),
                 Request(rid="a", prompt=(2,), max_new_tokens=2)])
    with pytest.raises(ValueError, match="empty prompt"):
        Request(rid="a", prompt=())


def test_engine_recurrent_arch():
    """xLSTM decode carries recurrent state, not a KV ring — the fresh-
    prefill splice must reset it per slot just the same."""
    cfg = _cfg("xlstm-125m")
    eng = ServeEngine(cfg, _plan(cfg), capacity=3, cache_len=16)
    reqs = _reqs(cfg, 5, tokens=4, prompt_len=2)
    batched = eng.run(reqs)
    assert eng.stats.peak_active == 3
    sequential = eng.run(reqs, max_active=1)
    for r in reqs:
        assert batched[r.rid].tokens == sequential[r.rid].tokens, r.rid


def test_prefill_cache_matches_forward_logits():
    """The scan-of-decode prefill's last-position logits agree with the
    full-sequence forward (same params, same plan)."""
    from repro.models.model import init_cache, model_specs
    from repro.models.params import init_params
    cfg = _cfg()
    plan = _plan(cfg)
    from repro.serve.step import make_prefill_cache
    params = init_params(model_specs(cfg), jax.random.key(0))
    prompt = jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)
    _, last, _ = make_prefill_cache(cfg, None, plan)(
        params, init_cache(cfg, 1, 16), prompt)
    fwd, _ = make_prefill(cfg, None, plan)
    full = fwd(params, {"tokens": prompt})
    np.testing.assert_allclose(np.asarray(last[0]),
                               np.asarray(full[0, -1]),
                               rtol=2e-2, atol=2e-2)


def test_cache_batch_axes_match_cache_ranks():
    from repro.models.model import init_cache
    for name in ("stablelm-3b", "xlstm-125m"):
        cfg = _cfg(name)
        caches = init_cache(cfg, 3, 8)
        axes = cache_batch_axes(cfg)
        def check(c, ax):
            assert c.shape[ax] == 3, (name, c.shape, ax)
        jax.tree.map(check, caches, axes)


def test_vector_pos_decode_matches_scalar_rows():
    """decode_attention with a per-row position vector reproduces the
    scalar-pos rows exactly (the primitive under the engine contract)."""
    from repro.core.plan import build_contexts
    from repro.models.model import decode_step, init_cache, model_specs
    from repro.models.params import init_params
    cfg = _cfg()
    plan = _plan(cfg)
    ctxs = build_contexts(cfg, None, plan)
    params = init_params(model_specs(cfg), jax.random.key(1))
    B, S = 3, 8
    rng = np.random.RandomState(2)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B,)), jnp.int32)

    # scalar path: run each row alone at its own position, after seeding
    # that row's cache with `p` decode steps
    def row_state(b, p):
        c = init_cache(cfg, 1, S)
        for i in range(p):
            _, c = decode_step(params, c,
                               jnp.asarray([7 + b + i], jnp.int32),
                               jnp.int32(i), cfg, ctxs)
        return c

    pos = [2, 0, 4]
    per_row = []
    for b in range(B):
        c = row_state(b, pos[b])
        lg, _ = decode_step(params, c, toks[b:b + 1],
                            jnp.int32(pos[b]), cfg, ctxs)
        per_row.append(np.asarray(lg[0]))

    # vector path: same rows batched with a (B,) position vector
    from repro.serve.engine import _put_row, cache_batch_axes
    axes = cache_batch_axes(cfg)
    batch = init_cache(cfg, B, S)
    for b in range(B):
        batch = _put_row(batch, row_state(b, pos[b]), axes, b)
    lg, _ = decode_step(params, batch, toks,
                        jnp.asarray(pos, jnp.int32), cfg, ctxs)
    for b in range(B):
        np.testing.assert_array_equal(np.asarray(lg[b]), per_row[b])
