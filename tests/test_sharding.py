"""Rules resolution: divisibility fallbacks, used-axis tracking, provider
mappings — pure pspec logic (no multi-device mesh needed)."""
from dataclasses import dataclass

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # container lacks hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.core.providers import all_providers
from repro.core.segment import fragment
from repro.runtime.sharding import Rules


@dataclass
class FakeDevices:
    shape: tuple

    @property
    def size(self):
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclass
class FakeMesh:
    axis_names: tuple
    devices: FakeDevices


def mk_mesh(**axes):
    return FakeMesh(tuple(axes), FakeDevices(tuple(axes.values())))


MESH = mk_mesh(data=16, model=16)
MESH3 = mk_mesh(pod=2, data=16, model=16)


def test_divisible_dim_shards():
    r = Rules({"heads": "model", "embed": None}, MESH)
    assert r.pspec(("embed", "heads", None), (4096, 32, 128)) == \
        P(None, "model")


def test_indivisible_dim_falls_back():
    r = Rules({"kv_heads": ["model", None]}, MESH)
    assert r.pspec(("kv_heads",), (2,)) == P()


def test_used_axis_not_reused():
    r = Rules({"embed": "model", "ffn": "model"}, MESH)
    ps = r.pspec(("embed", "ffn"), (4096, 14336))
    assert ps == P("model")          # second dim blocked, trailing None cut


def test_multi_axis_candidate():
    r = Rules({"batch": [("pod", "data"), None]}, MESH3)
    assert r.pspec(("batch", None), (256, 128)) == P(("pod", "data"))
    # pod axis missing on the single-pod mesh -> resolves to data only
    r2 = Rules({"batch": [("pod", "data"), None]}, MESH)
    assert r2.pspec(("batch", None), (256, 128)) == P("data")


def test_fallback_chain():
    r = Rules({"batch": [("pod", "data", "model"), ("pod", "data"), None]},
              MESH3)
    # 128 % 512 != 0 -> falls to (pod,data)=32
    assert r.pspec(("batch",), (128,)) == P(("pod", "data"))
    # 512-divisible batch uses all three
    assert r.pspec(("batch",), (512,)) == P(("pod", "data", "model"))


@given(st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_pspec_never_shards_indivisible(heads, dim2):
    r = Rules({"heads": "model", "ffn": "data"}, MESH)
    ps = r.pspec(("heads", "ffn"), (heads, dim2))
    parts = list(ps) + [None] * (2 - len(ps))
    if parts[0] == "model":
        assert heads % 16 == 0
    if parts[1] == "data":
        assert dim2 % 16 == 0


@pytest.mark.parametrize("provider", sorted(all_providers()))
@pytest.mark.parametrize("arch", ["granite-8b", "qwen3-moe-30b-a3b",
                                  "xlstm-125m", "recurrentgemma-2b"])
def test_provider_mappings_resolve_for_all_params(provider, arch):
    """Every provider mapping must produce a valid PartitionSpec for every
    parameter of every arch (divisibility-safe by construction)."""
    from repro.models.model import model_specs
    from repro.models.params import param_pspecs
    cfg = get_arch(arch)
    p = all_providers()[provider]
    for seg in fragment(cfg):
        if not p.applicable(cfg, seg):
            continue
        mapping = p.mapping(cfg, {"data": 16, "model": 16},
                            frozenset(p.flags), seg)
        r = Rules(mapping, MESH)
        tree = model_specs(cfg)
        sub = tree.get(seg.name)
        if sub is None:
            continue
        pspecs = param_pspecs(sub, r)
        # every resolved axis must divide the dim
        import jax
        from repro.models.params import is_spec
        for spec, ps in zip(
                jax.tree.leaves(sub, is_leaf=is_spec),
                jax.tree.leaves(pspecs,
                                is_leaf=lambda x: isinstance(x, P))):
            parts = list(ps) + [None] * (len(spec.shape) - len(ps))
            for dim, part in zip(spec.shape, parts):
                if part is None:
                    continue
                axes = (part,) if isinstance(part, str) else part
                size = int(np.prod([dict(data=16, model=16)[a]
                                    for a in axes]))
                assert dim % size == 0, (provider, arch, spec.shape, ps)
