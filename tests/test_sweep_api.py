"""The typed sweep API: SweepSpec, BackendOptions/SearchOptions bundles,
and the guarantee that every spelling of the same sweep produces the
same plan and the same accounting."""
import json

import pytest

from repro.configs import get_arch, get_shape
from repro.core import (BackendOptions, ComParTuner, SearchOptions,
                        SweepDB, SweepSpec, load_sweep_json)

SPEC_JSON = {
    "providers": {"tensor_par": ["shard_vocab"], "fsdp": []},
    "clauses": {"remat": ["none", "dots"], "block_q": [16]},
    "globals": {"microbatches": [1, 2]},
}


@pytest.fixture()
def spec_path(tmp_path):
    p = tmp_path / "spec.json"
    p.write_text(json.dumps(SPEC_JSON))
    return str(p)


def _tuner(tmp_path, name):
    cfg = get_arch("stablelm-3b").smoke()
    shape = get_shape("train_4k").smoke()
    db = SweepDB(str(tmp_path / f"{name}.db"))
    return ComParTuner(cfg, shape, db=db, project=name,
                       executor="dryrun")


def _plan_key(plan):
    # identical modulo bookkeeping: meta carries the project name
    doc = {k: v for k, v in plan.to_json().items() if k != "meta"}
    return json.dumps(doc, sort_keys=True)


def _accounting(rep):
    return (rep.n_combinations, rep.n_done, rep.n_failed, rep.n_pruned,
            rep.n_scored, rep.n_cached, rep.n_shared, rep.n_knob_points,
            rep.n_mesh_points)


# --- SweepSpec --------------------------------------------------------------

def test_load_sweep_json_returns_typed_spec(spec_path):
    spec = load_sweep_json(spec_path)
    assert isinstance(spec, SweepSpec)
    assert spec.providers == ("tensor_par", "fsdp")
    assert spec.clauses["remat"] == ("none", "dots")
    assert spec.globals["microbatches"] == (1, 2)
    assert spec.meshes is None and spec.kernel_space is None


def test_spec_tuple_unpacking_shim_warns(spec_path):
    spec = load_sweep_json(spec_path)
    with pytest.warns(DeprecationWarning, match="4-tuple"):
        providers, clause_space, global_space, mesh_space = spec
    assert providers == ["tensor_par", "fsdp"]
    assert clause_space == spec.clauses
    assert global_space == spec.globals
    assert mesh_space is None


def test_spec_json_roundtrip(spec_path):
    spec = load_sweep_json(spec_path)
    again = SweepSpec.from_json(spec.to_json())
    assert again == spec
    # the mesh axis survives the round-trip as MeshSpecs
    doc = dict(SPEC_JSON, meshes=[None, {"data": 2}])
    s2 = SweepSpec.from_json(doc)
    assert s2.meshes is not None and len(s2.meshes) == 2
    assert SweepSpec.from_json(s2.to_json()) == s2


def test_sweep_spec_equals_bare_kwargs(tmp_path, spec_path):
    spec = load_sweep_json(spec_path)
    with _tuner(tmp_path, "via-spec") as t1:
        p1, r1 = t1.sweep(spec=spec, max_flags=1, backend="sequential")
    with _tuner(tmp_path, "via-kwargs") as t2:
        p2, r2 = t2.sweep(providers=list(spec.providers),
                          clause_space=spec.clauses,
                          global_space=spec.globals,
                          max_flags=1, backend="sequential")
    assert _plan_key(p1) == _plan_key(p2)
    assert _accounting(r1) == _accounting(r2)


def test_spec_conflicts_with_bare_axis_kwargs(tmp_path, spec_path):
    spec = load_sweep_json(spec_path)
    with _tuner(tmp_path, "conflict") as t:
        with pytest.raises(ValueError, match="providers"):
            t.sweep(providers=["fsdp"], spec=spec)
        with pytest.raises(ValueError, match="clause_space"):
            t.sweep(clause_space={"remat": ("none",)}, spec=spec)
        with pytest.raises(ValueError, match="global_space"):
            t.sweep(spec=spec, global_space={"microbatches": (1,)})
        with pytest.raises(ValueError, match="SweepSpec"):
            t.sweep(spec=("tensor_par",))


# --- kwarg bundles ----------------------------------------------------------

def test_backend_and_search_bundles_equal_bare_kwargs(tmp_path):
    kw = dict(providers=("tensor_par",),
              clause_space={"remat": ("none", "dots"), "kernel": ("xla",)},
              max_flags=0)
    with _tuner(tmp_path, "bare") as t1:
        p1, r1 = t1.sweep(backend="sequential", prune=True,
                          prune_margin=0.0, static_checks="strict", **kw)
    with _tuner(tmp_path, "bundled") as t2:
        p2, r2 = t2.sweep(
            backend=BackendOptions(backend="sequential"),
            search=SearchOptions(prune=True, prune_margin=0.0,
                                 static_checks="strict"), **kw)
    assert _plan_key(p1) == _plan_key(p2)
    assert _accounting(r1) == _accounting(r2)
    assert r1.static_rules == r2.static_rules


def test_bundle_conflicts_with_bare_twin(tmp_path):
    kw = dict(providers=("tensor_par",),
              clause_space={"remat": ("none",)}, max_flags=0)
    with _tuner(tmp_path, "clash") as t:
        with pytest.raises(ValueError, match="workers"):
            t.sweep(backend=BackendOptions(backend="thread", workers=2),
                    workers=3, **kw)
        with pytest.raises(ValueError, match="prune"):
            t.sweep(search=SearchOptions(prune=True), prune=True, **kw)
        with pytest.raises(ValueError, match="SearchOptions"):
            t.sweep(search={"prune": True}, **kw)
    # defaults inside the bundle never clash with default bare kwargs
    with _tuner(tmp_path, "noclash") as t:
        t.sweep(backend=BackendOptions(backend="sequential"),
                search=SearchOptions(), **kw)


def test_search_bundle_conflict_detected_against_spec(tmp_path, spec_path):
    # kernel_space arriving via SearchOptions collides with a spec that
    # would also set it — normalization order must catch it
    spec = load_sweep_json(spec_path)
    with _tuner(tmp_path, "order") as t:
        with pytest.raises(ValueError, match="kernel_space"):
            t.sweep(spec=spec,
                    search=SearchOptions(
                        kernel_space={"kernel": ("xla",)}))
