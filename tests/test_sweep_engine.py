"""The parallel / cached / pruned sweep engine.

Invariants: parallel == sequential, cached == fresh (identical CostTerms,
zero recompiles), pruning never changes the fused plan, Continue mode
resumes without recompiling, and the DB/deadline satellite fixes hold.
Backend suite: sequential, thread and process backends fuse byte-identical
plans; a hung process worker is killed by the hard timeout.
"""
import json
import threading
import time

import pytest

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.combinator import Combination
from repro.core.cost_model import CostTerms, combo_lower_bound
from repro.core.executor import CombinationFailed, deadline
from repro.core.segment import Segment, fragment
from repro.models.context import SegmentClause


def _plan_bytes(plan):
    """Byte-identity of the fused decisions: per-segment combinations AND
    the chosen knob point (the joint-argmin output)."""
    d = plan.to_json()
    return json.dumps({"segments": d["segments"], "knobs": d["knobs"]},
                      sort_keys=True).encode()

SPACE = {"remat": ("none", "full"), "kernel": ("xla",), "block_q": (16, 32),
         "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}


def _tuner(db, project, mode="new", **kw):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return ComParTuner(cfg, shape, mesh=None, db=db, project=project,
                       mode=mode, executor="dryrun", timeout_s=120), cfg, shape


def _sweep(tuner, **kw):
    return tuner.sweep(providers=["tensor_par", "fsdp"], clause_space=SPACE,
                       max_flags=1, **kw)


@pytest.fixture(scope="module")
def sequential():
    db = SweepDB(":memory:")
    tuner, cfg, shape = _tuner(db, "seq")
    plan, rep = _sweep(tuner, workers=1, use_cache=False, prune=False)
    return plan, rep


def test_parallel_agrees_with_sequential(sequential):
    plan_seq, rep_seq = sequential
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "par")
    plan_par, rep_par = _sweep(tuner, workers=4, use_cache=False, prune=False)
    assert plan_par.segments == plan_seq.segments
    assert rep_par.n_done == rep_seq.n_done
    assert rep_par.n_failed == rep_seq.n_failed == 0


def test_structural_sharing_compiles_unique_programs_once(sequential):
    _, rep = sequential
    # with no mesh all providers/flags collapse per segment-relevant clause:
    # far fewer compiles than rows, and every row still gets a result
    assert rep.n_scored < rep.n_combinations
    assert rep.n_scored + rep.n_shared == rep.n_done


def test_cache_hits_return_identical_costterms(sequential, tmp_path):
    plan1, rep1 = sequential
    db = SweepDB(str(tmp_path / "sweep.db"))
    t1, _, _ = _tuner(db, "c1")
    plan_a, rep_a = _sweep(t1, use_cache=True)
    assert rep_a.n_cached == 0
    t2, _, _ = _tuner(db, "c2")
    plan_b, rep_b = _sweep(t2, use_cache=True)
    # second sweep of the same config recompiles NOTHING
    assert rep_b.n_scored == 0
    assert rep_b.n_cached == rep_b.n_combinations
    assert plan_b.segments == plan_a.segments == plan1.segments
    # identical CostTerms row-for-row
    rows_a = {(r["segment"], r["cid"]): r["cost"]
              for r in db.results("c1") if r["status"] == "done"}
    rows_b = {(r["segment"], r["cid"]): r["cost"]
              for r in db.results("c2") if r["status"] == "done"}
    assert rows_a.keys() == rows_b.keys() and len(rows_a) > 0
    for k, cost in rows_a.items():
        assert CostTerms.from_dict(cost).as_dict() == \
            CostTerms.from_dict(rows_b[k]).as_dict()


def test_cache_survives_reopen(tmp_path):
    path = str(tmp_path / "sweep.db")
    t1, _, _ = _tuner(SweepDB(path), "p1")
    _sweep(t1, use_cache=True)
    t2, _, _ = _tuner(SweepDB(path), "p2")   # fresh connection
    _, rep = _sweep(t2, use_cache=True)
    assert rep.n_scored == 0
    assert rep.n_cached == rep.n_combinations


def test_pruning_never_changes_the_plan(sequential):
    plan_seq, rep_seq = sequential
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "pr")
    plan_pr, rep_pr = _sweep(tuner, workers=2, use_cache=False, prune=True,
                             prune_margin=0.0)
    assert plan_pr.segments == plan_seq.segments
    # every registered row is settled one way or another
    assert (rep_pr.n_done + rep_pr.n_failed + rep_pr.n_pruned
            == rep_pr.n_combinations)


def test_continue_mode_resumes_without_recompiling():
    db = SweepDB(":memory:")
    t1, _, _ = _tuner(db, "r", mode="new")
    plan1, rep1 = _sweep(t1, use_cache=False)
    assert rep1.n_scored > 0
    t2, _, _ = _tuner(db, "r", mode="continue")
    plan2, rep2 = _sweep(t2, use_cache=False)
    assert rep2.n_scored == 0            # all rows settled -> nothing to do
    assert rep2.n_done == rep1.n_done
    assert plan2.segments == plan1.segments


def test_lower_bound_is_below_measured_score(sequential):
    """The pruning certificate: bound <= true score for every scored row."""
    _, rep = sequential
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    segs = {s.name: s for s in fragment(cfg)}
    checked = 0
    for sname, rows in rep.per_segment.items():
        for combo, cost in rows:
            lb = combo_lower_bound(cfg, shape, segs[sname], combo)
            assert lb <= cost.total_s + 1e-12, (sname, combo.label())
            checked += 1
    assert checked > 0


def test_segment_signature_structural_identity():
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    a = Segment("g0", "stack", ("attn",), 2)
    b = Segment("g7", "stack", ("attn",), 2)      # same structure, new name
    c = Segment("g1", "stack", ("attn", "rec"), 2)
    assert a.signature(cfg, shape) == b.signature(cfg, shape)
    assert a.signature(cfg, shape) != c.signature(cfg, shape)
    # arch name is excluded; arch *fields* are not
    import dataclasses
    renamed = dataclasses.replace(cfg, name="other")
    wider = dataclasses.replace(cfg, d_model=cfg.d_model * 2)
    assert a.signature(renamed, shape) == a.signature(cfg, shape)
    assert a.signature(wider, shape) != a.signature(cfg, shape)


def test_relevant_clause_fields():
    embed = Segment("embed", "embed")
    head = Segment("head", "head")
    attn = Segment("g0", "stack", ("attn",), 2)
    moe = Segment("g0", "stack", ("attn_moe",), 2)
    rec = Segment("g0", "stack", ("rec",), 2)
    assert embed.relevant_clause_fields("train") == frozenset()
    assert head.relevant_clause_fields("train") == frozenset()
    assert {"remat", "kernel", "block_q"} <= attn.relevant_clause_fields("train")
    assert "cache_upcast" in attn.relevant_clause_fields("decode")
    assert "cache_upcast" not in attn.relevant_clause_fields("train")
    assert "moe_dispatch" in moe.relevant_clause_fields("train")
    assert "mlstm_chunk" in rec.relevant_clause_fields("train")


def test_irrelevant_clause_fields_share_scores(sequential):
    """Exactness of the projection: head-segment scores must be identical
    across combos that differ only in stack-only clause fields."""
    _, rep = sequential
    head_rows = rep.per_segment["head"]
    totals = {c.cid: t.total_s for c, t in head_rows}
    assert len(totals) > 1
    assert len(set(totals.values())) == 1


def test_cache_is_keyed_by_executor(tmp_path):
    """Analytic dry-run scores must never be served to a wall-clock sweep
    sharing the same DB file (and vice versa)."""
    db = SweepDB(str(tmp_path / "sweep.db"))
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
    t1 = ComParTuner(cfg, shape, mesh=None, db=db, project="dry",
                     mode="new", executor="dryrun", timeout_s=120)
    t1.sweep(providers=["fsdp"], clause_space=space, max_flags=0)
    t2 = ComParTuner(cfg, shape, mesh=None, db=db, project="wall",
                     mode="new", executor="wallclock", timeout_s=120)
    _, rep = t2.sweep(providers=["fsdp"], clause_space=space, max_flags=0)
    assert rep.n_cached == 0 and rep.n_scored > 0


def test_prune_disabled_under_boundary_cost_fusion():
    """The lower-bound certificate covers per-segment argmin only; under
    Viterbi fusion pruning must be switched off."""
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "bc")
    plan, rep = _sweep(tuner, prune=True, boundary_costs=True,
                       use_cache=False)
    assert rep.n_pruned == 0
    assert plan.meta["fusion"] == "viterbi-boundary"


def test_wallclock_clamps_workers(monkeypatch):
    """Concurrent timed runs contend on the device: a wallclock sweep must
    run its measurements sequentially even if workers>1 is requested."""
    from repro.core import executor as E
    seen = {}
    orig = E.ParallelSweepRunner.__init__

    def spy(self, ex, cfg, shape, *, workers=1, **kw):
        seen["workers"] = workers
        orig(self, ex, cfg, shape, workers=workers, **kw)

    monkeypatch.setattr(E.ParallelSweepRunner, "__init__", spy)
    import repro.core.tuner as T
    monkeypatch.setattr(T, "ParallelSweepRunner", E.ParallelSweepRunner)
    db = SweepDB(":memory:")
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
    t = ComParTuner(cfg, shape, mesh=None, db=db, project="wc",
                    mode="new", executor="wallclock", timeout_s=120)
    t.sweep(providers=["fsdp"], clause_space=space, max_flags=0,
            workers=8, use_cache=False)
    assert seen["workers"] == 1


def test_deadline_failures_are_not_cached(tmp_path):
    db = SweepDB(str(tmp_path / "sweep.db"))
    t1, _, _ = _tuner(db, "dl")
    t1.executor.timeout_s = 0.001   # soft-fail everything scored
    with pytest.raises(ValueError):  # nothing valid left -> fuse() refuses
        _sweep(t1, use_cache=True, workers=2)
    rows = db.results("dl")
    assert rows and all(r["status"] == "failed" for r in rows)
    assert db.cache_size() == 0
    # a retry with a sane budget recompiles (nothing poisoned)...
    t2, _, _ = _tuner(db, "dl2")
    _, rep2 = _sweep(t2, use_cache=True)
    assert rep2.n_done == rep2.n_combinations
    # ...and its good scores DO land in the cache
    assert db.cache_size() == rep2.n_scored


def test_wallclock_disables_prune():
    """combo_lower_bound divides by an analytic hw peak; against measured
    wall seconds the certificate doesn't hold, so prune must switch off."""
    db = SweepDB(":memory:")
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
    t = ComParTuner(cfg, shape, mesh=None, db=db, project="wp",
                    mode="new", executor="wallclock", timeout_s=120)
    _, rep = t.sweep(providers=["fsdp"], clause_space=space, max_flags=0,
                     prune=True, use_cache=False)
    assert rep.n_pruned == 0 and rep.n_done == rep.n_combinations


def test_unexpected_worker_exception_fails_row_not_sweep(monkeypatch):
    """A non-CombinationFailed bug in scoring must become a failed row;
    an escaping exception would abort the sweep mid-batch."""
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "boom")
    orig = tuner.executor.score_segment
    calls = {"n": 0}

    def flaky(cfg, shape, seg, combo, knobs=None):
        calls["n"] += 1
        if calls["n"] == 3:   # a stack group — its siblings still succeed
            raise ValueError("synthetic analysis bug")
        return orig(cfg, shape, seg, combo, knobs=knobs)

    monkeypatch.setattr(tuner.executor, "score_segment", flaky)
    plan, rep = _sweep(tuner, use_cache=False)
    assert rep.n_failed > 0
    assert rep.n_done + rep.n_failed == rep.n_combinations
    rows = [r for r in db.results("boom") if r["status"] == "failed"]
    assert any("ValueError" in r["error"] for r in rows)


# --- satellite fixes ---------------------------------------------------------

def test_db_record_unregistered_raises():
    db = SweepDB(":memory:")
    db.open_project("p", "new")
    with pytest.raises(KeyError):
        db.record("p", "g0", "deadbeef0000", status="done",
                  cost={"total_s": 1.0})


def test_db_record_many_partial_unregistered_raises_and_rolls_back():
    db = SweepDB(":memory:")
    db.open_project("p", "new")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    db.register("p", "g0", combo)
    with pytest.raises(KeyError):
        db.record_many("p", [
            {"segment": "g0", "cid": combo.cid, "status": "done",
             "cost": {"total_s": 1.0}},
            {"segment": "g0", "cid": "missing000000", "status": "done"},
        ])
    assert db.status("p", "g0", combo.cid) == "pending"


def test_deadline_off_main_thread_soft_fails():
    out = {}

    def burn(cpu_s):
        t0 = time.thread_time()
        while time.thread_time() - t0 < cpu_s:
            sum(i * i for i in range(1000))

    def body():
        try:
            with deadline(1):
                burn(1.1)    # the soft deadline is CPU time, not wall
            out["raised"] = False
        except CombinationFailed as e:
            out["raised"] = True
            out["msg"] = str(e)

    t = threading.Thread(target=body)
    t.start()
    t.join()
    assert out["raised"] and "soft" in out["msg"]


def test_deadline_off_main_thread_passes_within_budget():
    out = {}

    def body():
        with deadline(30):
            out["ok"] = True

    t = threading.Thread(target=body)
    t.start()
    t.join()
    assert out.get("ok")


# --- Scheduler -> Backend -> Recorder pipeline -------------------------------


def test_backend_equivalence_sequential_thread_process(sequential):
    """The acceptance invariant: sequential, thread(2) and process(2)
    backends fuse byte-identical plans on the smoke config."""
    plan_ref, rep_ref = sequential
    ref = _plan_bytes(plan_ref)

    t_seq, _, _ = _tuner(SweepDB(":memory:"), "be-seq")
    plan_s, rep_s = _sweep(t_seq, backend="sequential", workers=4,
                           use_cache=False, prune=False)
    assert _plan_bytes(plan_s) == ref

    t_thr, _, _ = _tuner(SweepDB(":memory:"), "be-thr")
    plan_t, rep_t = _sweep(t_thr, backend="thread", workers=2,
                           use_cache=False, prune=False)
    assert _plan_bytes(plan_t) == ref

    t_prc, _, _ = _tuner(SweepDB(":memory:"), "be-prc")
    plan_p, rep_p = _sweep(t_prc, backend="process", workers=2,
                           use_cache=False, prune=False)
    assert _plan_bytes(plan_p) == ref
    assert (rep_p.n_done, rep_p.n_failed, rep_p.n_scored, rep_p.n_shared) \
        == (rep_ref.n_done, 0, rep_ref.n_scored, rep_ref.n_shared)


def test_process_backend_hard_timeout_kills_hung_worker():
    """A worker stuck past timeout_s is killed (requeued once, then failed
    transient) within ~2 * timeout_s wall-clock — the sweep cannot hang."""
    from repro.core.backends import JobSpec, ProcessBackend
    from repro.core.executor import SleepExecutor

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    job = JobSpec("hung", seg, combo, segments=(seg.name,))

    timeout_s = 2.0
    backend = ProcessBackend(SleepExecutor(sleep_s=600.0), cfg, shape,
                             workers=2, timeout_s=timeout_s)
    try:
        backend.warmup()            # keep jax import out of the timing window
        t0 = time.monotonic()
        outs = list(backend.run([job]))
        elapsed = time.monotonic() - t0
    finally:
        backend.close()
    assert len(outs) == 1
    out = outs[0]
    assert out.status == "failed" and out.transient
    assert out.attempts == 2 and "killed" in out.error
    # two attempts, each killed at timeout_s * (1 + kill_grace) — the
    # grace window lets a worker's own SIGALRM report gracefully first
    budget = 2 * timeout_s * (1 + ProcessBackend.kill_grace) + 1.0
    assert elapsed < budget, f"hard kill too slow: {elapsed:.1f}s"


def test_process_backend_crash_requeues_once_then_fails_transient():
    from repro.core.backends import JobSpec, ProcessBackend
    from repro.core.executor import CrashExecutor

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())

    backend = ProcessBackend(CrashExecutor(), cfg, shape, workers=2,
                             timeout_s=60)
    try:
        backend.warmup()
        outs = list(backend.run(
            [JobSpec("boom", seg, combo, segments=(seg.name,))]))
    finally:
        backend.close()
    assert len(outs) == 1
    out = outs[0]
    assert out.status == "failed" and out.transient and out.attempts == 2
    assert "crashed" in out.error


def test_process_backend_honors_use_cache_off(tmp_path):
    """use_cache=False must force real recompiles even on a file-backed DB
    whose score_cache is warm — workers must not get a cache reader."""
    db = SweepDB(str(tmp_path / "sweep.db"))
    t1, _, _ = _tuner(db, "warm")
    _sweep(t1, use_cache=True)                      # populate the cache
    t2, _, _ = _tuner(db, "nocache")
    _, rep = _sweep(t2, backend="process", workers=2, use_cache=False)
    assert rep.n_cached == 0
    assert rep.n_scored > 0
    assert rep.n_done == rep.n_combinations


def test_jobspec_joboutcome_wire_roundtrip():
    """The process/remote wire format: pure JSON both ways, including the
    GlobalKnobs point the program is built under."""
    from repro.core.backends import JobOutcome, JobSpec
    from repro.core.combinator import GlobalKnobs

    seg = Segment("g0", "stack", ("attn", "rec"), 3)
    combo = Combination("tensor_par", frozenset({"shard_vocab"}),
                        SegmentClause(remat="dots", block_q=64))
    spec = JobSpec("k1", seg, combo, segments=("g0", "g3"), bound_s=1.5,
                   signature="sig", eff_cid="ec",
                   knobs=GlobalKnobs(microbatches=2, donate=False))
    wire = json.loads(json.dumps(spec.to_json()))
    back = JobSpec.from_json(wire)
    assert back == spec and isinstance(back.seg.pattern, tuple)
    assert isinstance(back.segments, tuple)
    assert back.knobs == spec.knobs
    # knobless (hand-built / pre-knob) specs stay knobless
    bare = JobSpec("k2", seg, combo)
    assert JobSpec.from_json(
        json.loads(json.dumps(bare.to_json()))).knobs is None

    out = JobOutcome("k1", "failed", cost=None, error="deadline",
                     transient=True, attempts=2)
    assert JobOutcome.from_json(json.loads(json.dumps(out.to_json()))) == out


def test_executor_to_spec_serializes_mesh_as_meshspec():
    """A fixed-mesh executor crosses the wire: its mesh travels as a
    declarative MeshSpec (never device handles) and the worker-side
    rebuild materializes the same topology against local devices —
    meshed sweeps are no longer locked out of process/remote backends."""
    from repro.core.backends import executor_from_spec, executor_to_spec
    from repro.core.executor import DryRunExecutor
    from repro.core.meshspec import MeshSpec

    mesh = MeshSpec.of(data=1).to_mesh()
    spec = json.loads(json.dumps(
        executor_to_spec(DryRunExecutor(mesh, timeout_s=60))))
    assert spec["mesh"] == {"axes": [["data", 1]], "device_kind": ""}
    rebuilt = executor_from_spec(spec)
    assert rebuilt.mesh is not None
    assert tuple(rebuilt.mesh.axis_names) == ("data",)
    assert rebuilt.n_chips == 1
    # meshless executors stay meshless on the wire
    bare = executor_to_spec(DryRunExecutor(None, timeout_s=60))
    assert bare["mesh"] is None
    assert executor_from_spec(bare).mesh is None


def test_arch_shape_specs_roundtrip_via_registry():
    import dataclasses

    from repro.configs import (arch_from_spec, arch_to_spec, shape_from_spec,
                               shape_to_spec)

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    # registry fast path: a name-resolvable spec returns the canonical cfg
    assert arch_from_spec(json.loads(json.dumps(arch_to_spec(cfg)))) == cfg
    assert shape_from_spec(
        json.loads(json.dumps(shape_to_spec(shape)))) == shape
    # ad-hoc configs (fields diverge from the registry) rebuild from fields
    custom = dataclasses.replace(cfg, d_model=cfg.d_model * 2)
    rebuilt = arch_from_spec(json.loads(json.dumps(arch_to_spec(custom))))
    assert rebuilt == custom and isinstance(rebuilt.block_pattern, tuple)


def test_deadline_failures_are_transient():
    """Cacheability is decided by the structured ``transient`` flag on the
    raising executor, not by substring-matching the error text."""
    out = {}

    def body():
        try:
            with deadline(1):
                t0 = time.thread_time()
                while time.thread_time() - t0 < 1.1:
                    sum(i * i for i in range(1000))
        except CombinationFailed as e:
            out["transient"] = e.transient

    t = threading.Thread(target=body)
    t.start()
    t.join()
    assert out["transient"] is True
    assert CombinationFailed("lowering failed").transient is False


def test_transient_rows_counted_not_scored(monkeypatch):
    """Report accounting: a transient failure neither counts as a scored
    program nor lands in the cache; deterministic failures are cached but
    not counted as compiled programs either."""
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "acct")
    orig = tuner.executor.score_segment
    calls = {"n": 0}

    def flaky(cfg, shape, seg, combo, knobs=None):
        # fail two of the stack segment's four unique programs so every
        # segment keeps at least one valid row and fusion still succeeds
        if seg.kind == "stack":
            calls["n"] += 1
            if calls["n"] == 1:
                raise CombinationFailed("deadline 0s exceeded (synthetic)",
                                        transient=True)
            if calls["n"] == 2:
                raise CombinationFailed("ShardingError: synthetic")
        return orig(cfg, shape, seg, combo, knobs=knobs)

    monkeypatch.setattr(tuner.executor, "score_segment", flaky)
    # transient_retries=0: the default in-sweep retry round would score
    # the once-flaky program on its second dispatch (that recovery has
    # its own test in test_faults.py) — this test pins the accounting
    # of transients that survive to the report
    _, rep = _sweep(tuner, use_cache=True, transient_retries=0)
    assert rep.n_transient > 0
    assert rep.n_failed >= rep.n_transient
    assert rep.n_scored + rep.n_shared == rep.n_done
    # cache holds the done programs + the deterministic failure only
    assert db.cache_size() == rep.n_scored + 1
    rows = db.results("acct")
    n_det = sum(1 for r in rows if r["status"] == "failed"
                and "ShardingError" in r["error"])
    n_soft = sum(1 for r in rows if r["status"] == "failed"
                 and "synthetic" in r["error"] and "deadline" in r["error"])
    assert n_det > 0 and n_soft == rep.n_transient


def test_cache_tag_isolation_contract(tmp_path):
    """The docs/sweep_engine.md contract: an entry written under
    ``dryrun:tpu-v5e`` must never be served to ``wallclock:r5:*`` — and
    wall-clock tags embed the LOCAL PLATFORM, because empirical timings
    from different silicon are never interchangeable (the analytic
    dryrun tag embeds its hardware model name instead)."""
    from repro.core.executor import DryRunExecutor, WallClockExecutor

    import jax
    assert DryRunExecutor(None).cache_tag == "dryrun:tpu-v5e"
    assert WallClockExecutor(None).cache_tag == \
        f"wallclock:r5:{jax.devices()[0].platform}"

    db = SweepDB(str(tmp_path / "iso.db"))
    db.cache_put_many([{"signature": "sig", "shape": "train:32x4",
                        "mesh": "local/dryrun:tpu-v5e", "cid": "ec",
                        "status": "done", "cost": {"total_s": 1.0}}])
    assert db.cache_get("sig", "train:32x4", "local/dryrun:tpu-v5e",
                        "ec") is not None
    assert db.cache_get("sig", "train:32x4", "local/wallclock:r5",
                        "ec") is None


# --- the GlobalKnobs outer axis ----------------------------------------------


def test_relevant_knob_fields():
    from repro.core.combinator import DEFAULT_GLOBAL_SPACE
    stack = Segment("g0", "stack", ("attn",), 2)
    embed = Segment("embed", "embed")
    head = Segment("head", "head")
    for seg in (stack, embed, head):
        # training wraps every segment in a backward pass: microbatching
        # and donation reach all of them
        assert seg.relevant_knob_fields("train") == \
            frozenset({"microbatches", "donate"})
        # inference shapes: no knob reaches any segment program
        assert seg.relevant_knob_fields("decode") == frozenset()
        assert seg.relevant_knob_fields("prefill") == frozenset()
    # opt_state_dtype (the optimizer update) is never part of a segment
    # program — sweeping it must be free on every shape
    for kind in ("train", "decode", "prefill"):
        assert "opt_state_dtype" not in stack.relevant_knob_fields(kind)
    # every relevant field is a real GlobalKnobs field
    assert stack.relevant_knob_fields("train") <= set(DEFAULT_GLOBAL_SPACE)


def test_nonreaching_knob_sweep_adds_zero_compiles(sequential):
    """The knob-relevance projection: sweeping a knob that reaches no
    segment program compiles nothing new — the rows fold into the same
    structural groups (score sharing across the knob axis)."""
    _, rep1 = sequential
    tuner, _, _ = _tuner(SweepDB(":memory:"), "osd")
    plan, rep = _sweep(tuner, use_cache=False,
                       global_space={"opt_state_dtype":
                                     ("float32", "bfloat16")})
    assert rep.n_knob_points == 2
    assert rep.n_combinations == 2 * rep1.n_combinations
    assert rep.n_scored == rep1.n_scored           # ZERO extra compiles
    assert rep.n_done == rep.n_combinations
    # the argmin ties across the two points; the tie-break is
    # deterministic — the first grid point wins
    assert plan.knobs.opt_state_dtype == "float32"
    assert len(rep.per_knob_total_s) == 2
    assert len(set(rep.per_knob_total_s.values())) == 1   # identical totals


def test_nonreaching_knob_sweep_is_free_on_decode_shapes():
    """On inference shapes NO knob reaches the program — even the
    microbatch axis sweeps for free."""
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("decode_32k").smoke()
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}

    def sweep(project, **kw):
        t = ComParTuner(cfg, shape, mesh=None, db=SweepDB(":memory:"),
                        project=project, mode="new", executor="dryrun",
                        timeout_s=120)
        return t.sweep(providers=["fsdp"], clause_space=space,
                       max_flags=0, use_cache=False, **kw)

    _, rep1 = sweep("one")
    _, rep2 = sweep("two", global_space={"microbatches": (1, 2)})
    assert rep2.n_combinations == 2 * rep1.n_combinations
    assert rep2.n_scored == rep1.n_scored


def test_reaching_knob_joint_argmin_matches_brute_force(sequential):
    """The acceptance invariant: a program-reaching knob (microbatches on
    a train shape) changes per-segment scores, and the returned
    ``plan.knobs`` is the joint argmin — verified against the brute-force
    reference of one independent single-point sweep per knob point."""
    from repro.core.combinator import GlobalKnobs
    _, rep1 = sequential
    tuner, _, _ = _tuner(SweepDB(":memory:"), "mb")
    plan, rep = _sweep(tuner, use_cache=False,
                       global_space={"microbatches": (1, 2)})
    # microbatches reaches every train segment: every unique program
    # compiles once per knob point
    assert rep.n_scored == 2 * rep1.n_scored
    totals = rep.per_knob_total_s
    assert len(totals) == 2 and len(set(totals.values())) == 2

    # brute force: one fixed-knobs sweep per point, argmin of the totals
    ref = {}
    for mb in (1, 2):
        t = _tuner(SweepDB(":memory:"), f"ref{mb}")[0]
        p, _ = _sweep(t, use_cache=False,
                      knobs=GlobalKnobs(microbatches=mb))
        ref[mb] = p.meta["predicted_total_s"]
    best_mb = min(ref, key=ref.get)
    assert plan.knobs.microbatches == best_mb
    assert abs(plan.meta["predicted_total_s"] - ref[best_mb]) < 1e-15
    assert plan.meta["fusion"] == "per-segment-argmin+knob-argmin"


def test_backend_equivalence_extends_to_knob_axis(sequential):
    """sequential/thread/process sweeps over the same global_space fuse
    byte-identical plans — segments AND chosen knobs."""
    space = {"microbatches": (1, 2),
             "opt_state_dtype": ("float32", "bfloat16")}
    plans = {}
    for backend, workers in (("sequential", 1), ("thread", 2),
                             ("process", 2)):
        t, _, _ = _tuner(SweepDB(":memory:"), f"kbe-{backend}")
        plan, rep = _sweep(t, backend=backend, workers=workers,
                           use_cache=False, global_space=space)
        plans[backend] = (plan, rep)
        t.close()
    ref_bytes = _plan_bytes(plans["sequential"][0])
    ref_rep = plans["sequential"][1]
    for backend, (plan, rep) in plans.items():
        assert _plan_bytes(plan) == ref_bytes, backend
        assert (rep.n_done, rep.n_failed, rep.n_scored) == \
            (ref_rep.n_done, 0, ref_rep.n_scored), backend


def test_effective_cid_v2_never_aliases_v1_cache_rows():
    """Pre-knob score_cache rows must never be served to the knob-aware
    engine: the v2 effective cid hashes a versioned blob that includes
    the knob projection, so it differs from the v1 hash even for the
    same mapping + clause content."""
    import hashlib

    from repro.core.combinator import GlobalKnobs, effective_cid

    combo = Combination("fsdp", frozenset(), SegmentClause())
    relevant = frozenset({"remat", "kernel"})

    def v1_hash(map_key):
        cl = {f: getattr(combo.clause, f) for f in sorted(relevant)}
        blob = json.dumps({"map": map_key, "clause": cl},
                          sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    # the pre-refactor key component never equals the new one
    assert effective_cid(combo, relevant, "local") != v1_hash("local")
    assert effective_cid(combo, relevant, "local",
                         GlobalKnobs(), frozenset()) != v1_hash("local")
    # knob projection: irrelevant knob fields collapse, relevant split
    k1, k2 = GlobalKnobs(microbatches=1), GlobalKnobs(microbatches=2)
    rel = frozenset({"microbatches"})
    assert effective_cid(combo, relevant, "local", k1, rel) != \
        effective_cid(combo, relevant, "local", k2, rel)
    assert effective_cid(combo, relevant, "local", k1, frozenset()) == \
        effective_cid(combo, relevant, "local", k2, frozenset())
    # same projection -> same cid: points differing only in fields
    # outside the relevant set collapse
    osd = GlobalKnobs(opt_state_dtype="bfloat16")
    assert effective_cid(combo, relevant, "local", osd, rel) == \
        effective_cid(combo, relevant, "local", k1, rel)


def test_knob_rows_and_default_rows_share_cache_when_projection_agrees(
        tmp_path):
    """Cross-sweep score sharing over the knob axis: a warm cache written
    by a default single-point sweep serves a global_space sweep's rows
    whose knob projection matches (mb=1), so only the mb=2 programs
    compile."""
    db = SweepDB(str(tmp_path / "sweep.db"))
    t1, _, _ = _tuner(db, "warm")
    _, rep1 = _sweep(t1, use_cache=True)
    assert rep1.n_scored > 0
    t2, _, _ = _tuner(db, "knobbed")
    _, rep2 = _sweep(t2, use_cache=True,
                     global_space={"microbatches": (1, 2)})
    # mb=1 rows: all cache hits; mb=2 rows: compiled fresh
    assert rep2.n_cached == rep1.n_combinations
    assert rep2.n_scored == rep1.n_scored


def test_paper_count_charges_only_swept_knob_fields():
    from repro.core.combinator import swept_knob_fields
    assert swept_knob_fields(None) == ()
    assert swept_knob_fields({"microbatches": (1,)}) == ()
    assert swept_knob_fields({"microbatches": (1, 2),
                              "donate": (True,),
                              "opt_state_dtype": ("float32", "bfloat16")}) \
        == ("microbatches", "opt_state_dtype")

    # a fixed-knobs sweep charges rtl=0; sweeping one knob field doubles
    # the (2^{rtl+d}-1) factor (+1 in the exponent)
    t1, _, _ = _tuner(SweepDB(":memory:"), "pc1")
    _, rep1 = _sweep(t1, use_cache=False)
    t2, _, _ = _tuner(SweepDB(":memory:"), "pc2")
    _, rep2 = _sweep(t2, use_cache=False,
                     global_space={"opt_state_dtype":
                                   ("float32", "bfloat16")})
    assert rep1.paper_count < rep2.paper_count
    assert "realized=" in rep1.summary()
    assert "paper_formula_upper_bound=" in rep1.summary()


def test_process_backend_pool_survives_across_runs():
    """The worker-reuse satellite: successive run() calls on one process
    backend reuse the same warm workers instead of paying a fresh jax
    import per call (what keeps an outer knob axis cheap)."""
    from repro.core.backends import JobSpec, ProcessBackend
    from repro.core.executor import SleepExecutor

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())

    backend = ProcessBackend(SleepExecutor(sleep_s=0.01), cfg, shape,
                             workers=1, timeout_s=60)
    try:
        backend.warmup()
        pids0 = sorted(w.proc.pid for w in backend._pool)
        out1 = list(backend.run(
            [JobSpec("j1", seg, combo, segments=(seg.name,))]))
        assert [o.status for o in out1] == ["done"]
        assert sorted(w.proc.pid for w in backend._pool) == pids0
        out2 = list(backend.run(
            [JobSpec("j2", seg, combo, segments=(seg.name,))]))
        assert [o.status for o in out2] == ["done"]
        assert sorted(w.proc.pid for w in backend._pool) == pids0
        assert all(w.proc.is_alive() for w in backend._pool)
    finally:
        backend.close()
    assert backend._pool == []


def test_tuner_reuses_process_engine_across_sweeps():
    """Tuner-level worker reuse: two sweeps on one tuner share one cached
    process backend (same warm pool), released by tuner.close()."""
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "reuse")
    space2 = dict(SPACE, block_q=(64,))
    try:
        _sweep(tuner, backend="process", workers=1, use_cache=False)
        assert len(tuner._engines) == 1
        engine = next(iter(tuner._engines.values()))
        pids = sorted(w.proc.pid for w in engine._pool)
        assert pids, "pool should stay warm after the first sweep"
        # a second sweep with new rows reuses the same engine + workers
        tuner.sweep(providers=["tensor_par", "fsdp"], clause_space=space2,
                    max_flags=1, backend="process", workers=1,
                    use_cache=False)
        assert len(tuner._engines) == 1
        assert next(iter(tuner._engines.values())) is engine
        assert sorted(w.proc.pid for w in engine._pool) == pids
    finally:
        tuner.close()
    assert tuner._engines == {}


def test_incumbents_are_scoped_per_knob_point():
    """Pruning with a swept knob axis must compare against the SAME knob
    point's incumbents: a cheap mb=1 score must never prune an mb=2 row
    (each point needs its own per-segment argmin for the joint solve).
    Plan equality with the unpruned sweep is the observable contract."""
    t1, _, _ = _tuner(SweepDB(":memory:"), "np")
    plan_ref, rep_ref = _sweep(t1, use_cache=False, prune=False,
                               global_space={"microbatches": (1, 2)})
    t2, _, _ = _tuner(SweepDB(":memory:"), "pp")
    plan_pr, rep_pr = _sweep(t2, use_cache=False, prune=True,
                             prune_margin=0.0,
                             global_space={"microbatches": (1, 2)})
    assert _plan_bytes(plan_pr) == _plan_bytes(plan_ref)
    assert rep_pr.per_knob_total_s == rep_ref.per_knob_total_s


# --- PR 4 hardening satellites -----------------------------------------------


def test_cache_put_many_keep_best_semantics(tmp_path):
    """insert-if-absent / keep-best: a stale batch can never clobber a
    fresher equal-or-better row (the INSERT OR REPLACE regression)."""
    db = SweepDB(str(tmp_path / "kb.db"))
    key = dict(signature="s", shape="sh", mesh="m", cid="c")
    db.cache_put_many([{**key, "status": "done", "cost": {"total_s": 1.0}}])
    # a stale in-flight batch with a worse score does NOT clobber...
    db.cache_put_many([{**key, "status": "done", "cost": {"total_s": 2.0}}])
    assert db.cache_get("s", "sh", "m", "c")["cost"]["total_s"] == 1.0
    # ...a strictly better score does win...
    db.cache_put_many([{**key, "status": "done", "cost": {"total_s": 0.5}}])
    assert db.cache_get("s", "sh", "m", "c")["cost"]["total_s"] == 0.5
    # ...an equal score keeps the incumbent (first-writer-wins)...
    db.cache_put_many([{**key, "status": "done", "cost": {"total_s": 0.5,
                                                          "flops": 99.0}}])
    assert "flops" not in db.cache_get("s", "sh", "m", "c")["cost"]
    # ...and a failure never displaces a done row
    db.cache_put_many([{**key, "status": "failed", "error": "boom"}])
    hit = db.cache_get("s", "sh", "m", "c")
    assert hit["status"] == "done" and hit["cost"]["total_s"] == 0.5
    # done DOES displace failed
    key2 = dict(signature="s2", shape="sh", mesh="m", cid="c")
    db.cache_put_many([{**key2, "status": "failed", "error": "boom"}])
    db.cache_put_many([{**key2, "status": "done", "cost": {"total_s": 3.0}}])
    assert db.cache_get("s2", "sh", "m", "c")["status"] == "done"
    assert db.cache_size() == 2


def test_cache_put_many_two_interleaved_writers(tmp_path):
    """The regression scenario: two sweeps on one DB file, the slower
    one's in-flight batch lands after the fresher (better) row — the
    better row must survive, and both connections must see it."""
    path = str(tmp_path / "shared.db")
    a, b = SweepDB(path), SweepDB(path)
    key = dict(signature="s", shape="sh", mesh="m", cid="c")
    # both sweeps scored the same group; b commits first with the better
    # score, a's stale batch replays afterwards
    b.cache_put_many([{**key, "status": "done", "cost": {"total_s": 1.0}}])
    a.cache_put_many([{**key, "status": "done", "cost": {"total_s": 1.5}}])
    for conn in (a, b):
        assert conn.cache_get("s", "sh", "m", "c")["cost"]["total_s"] == 1.0
    # interleaved failure/success across connections
    key2 = dict(signature="s2", shape="sh", mesh="m", cid="c")
    a.cache_put_many([{**key2, "status": "done", "cost": {"total_s": 2.0}}])
    b.cache_put_many([{**key2, "status": "failed", "error": "stale"}])
    assert b.cache_get("s2", "sh", "m", "c")["status"] == "done"


def test_score_cache_migrates_pre_total_s_schema(tmp_path):
    """A DB created before the keep-best column exists is migrated in
    place, including backfilled totals so legacy rows stay beatable."""
    import sqlite3

    path = str(tmp_path / "old.db")
    conn = sqlite3.connect(path)
    conn.execute(
        "CREATE TABLE score_cache (signature TEXT, shape TEXT, mesh TEXT, "
        "cid TEXT, status TEXT, cost TEXT, error TEXT, created REAL, "
        "PRIMARY KEY (signature, shape, mesh, cid))")
    conn.execute(
        "INSERT INTO score_cache VALUES ('s','sh','m','c','done',"
        "'{\"total_s\": 2.0}','',0)")
    conn.commit()
    conn.close()
    db = SweepDB(path)
    assert db.cache_get("s", "sh", "m", "c")["cost"]["total_s"] == 2.0
    # keep-best works against the migrated row: better wins, worse doesn't
    db.cache_put_many([{"signature": "s", "shape": "sh", "mesh": "m",
                        "cid": "c", "status": "done",
                        "cost": {"total_s": 3.0}}])
    assert db.cache_get("s", "sh", "m", "c")["cost"]["total_s"] == 2.0
    db.cache_put_many([{"signature": "s", "shape": "sh", "mesh": "m",
                        "cid": "c", "status": "done",
                        "cost": {"total_s": 1.0}}])
    assert db.cache_get("s", "sh", "m", "c")["cost"]["total_s"] == 1.0


def test_legacy_done_row_without_total_stays_beatable(tmp_path):
    """A 'done' row whose cost blob carries no total (so the migration
    backfill left total_s NULL) must not become an unbeatable fixed
    point of the keep-best comparison."""
    db = SweepDB(str(tmp_path / "nl.db"))
    db.conn.execute(
        "INSERT INTO score_cache (signature, shape, mesh, cid, status, "
        "cost, error, created, total_s) VALUES "
        "('s','sh','m','c','done','{}','',0,NULL)")
    db.conn.commit()
    db.cache_put_many([{"signature": "s", "shape": "sh", "mesh": "m",
                        "cid": "c", "status": "done",
                        "cost": {"total_s": 5.0}}])
    assert db.cache_get("s", "sh", "m", "c")["cost"]["total_s"] == 5.0


def test_next_job_skips_excluded_worker():
    """Dispatch unit: a job is never handed back to a worker id it died
    on; a non-excluded worker still gets it, in queue order."""
    from collections import deque

    from repro.core.backends import JobSpec, ProcessBackend
    from repro.core.backends.process import _Worker
    from repro.core.executor import SleepExecutor

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    backend = ProcessBackend(SleepExecutor(sleep_s=0.01), cfg, shape,
                             workers=2)
    j1 = JobSpec("j1", seg, combo, segments=(seg.name,))
    j2 = JobSpec("j2", seg, combo, segments=(seg.name,))
    excluded = {"j1": {0}}
    w0, w1 = _Worker(None, None, 0), _Worker(None, None, 1)

    queue = deque([j1, j2])
    job, pruned = backend._next_job(w0, queue, excluded, {})
    assert job is j2 and not pruned      # j1 skipped, left for another worker
    assert list(queue) == [j1]
    job, _ = backend._next_job(w1, queue, excluded, {})
    assert job is j1 and not queue


def test_crash_requeue_dispatches_to_a_different_worker():
    """The requeue-diversification satellite, end-to-end: a job whose
    program kills its worker is retried on a DIFFERENT worker id — the
    lost worker (and whatever inherits its slot) is excluded."""
    from repro.core.backends import JobSpec, ProcessBackend
    from repro.core.executor import CrashExecutor

    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    seg = next(s for s in fragment(cfg) if s.kind == "stack")
    combo = Combination("fsdp", frozenset(), SegmentClause())

    backend = ProcessBackend(CrashExecutor(), cfg, shape, workers=2,
                             timeout_s=60)
    try:
        backend.warmup()
        outs = list(backend.run(
            [JobSpec("boom", seg, combo, segments=(seg.name,))]))
    finally:
        backend.close()
    assert len(outs) == 1
    assert outs[0].status == "failed" and outs[0].transient
    assert outs[0].attempts == 2
    log = backend.dispatch_log
    assert [k for k, _ in log] == ["boom", "boom"]
    wids = [w for _, w in log]
    assert wids[0] != wids[1], "retry burned on the worker the job died on"


def test_sweep_after_injected_failure_completes(monkeypatch):
    """tuner exception-safety: an error mid-sweep must not leave the
    cached process engine poisoned — the next sweep on the same tuner
    culls dead workers and completes; close() stays idempotent."""
    import repro.core.tuner as T

    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "injected")

    class BoomRecorder(T.Recorder):
        def outcome(self, group, out):
            raise RuntimeError("injected recorder failure")

    with monkeypatch.context() as m:
        m.setattr(T, "Recorder", BoomRecorder)
        with pytest.raises(RuntimeError, match="injected"):
            _sweep(tuner, backend="process", workers=1, use_cache=False)

    assert len(tuner._engines) == 1
    engine = next(iter(tuner._engines.values()))
    # simulate the aborted sweep also stranding dead workers in the pool
    for w in list(engine._pool):
        w.proc.terminate()
        w.proc.join(timeout=10)
    # the same tuner/project sweeps to completion (rows are still pending)
    plan, rep = _sweep(tuner, backend="process", workers=1, use_cache=False)
    assert rep.n_done == rep.n_combinations and rep.n_failed == 0
    assert next(iter(tuner._engines.values())) is engine  # engine reused
    assert all(w.proc.is_alive() for w in engine._pool)
    tuner.close()
    tuner.close()                       # idempotent
    assert tuner._engines == {}


def test_build_contexts_records_substitution(caplog):
    """A plan missing a segment must substitute loudly: warning + meta."""
    import logging

    from repro.core.plan import Plan, build_contexts

    cfg = get_arch("granite-8b").smoke()
    combo = Combination("fsdp", frozenset(), SegmentClause())
    plan = Plan({"g0": combo})
    with caplog.at_level(logging.WARNING, logger="repro.plan"):
        ctxs = build_contexts(cfg, None, plan)
    assert set(ctxs) == {s.name for s in fragment(cfg)}
    subs = plan.meta["substituted_segments"]
    assert set(subs) == {"embed", "head"}
    assert subs["embed"]["from"] == "g0"
    assert any("substituting" in r.message for r in caplog.records)
