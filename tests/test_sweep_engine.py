"""The parallel / cached / pruned sweep engine.

Invariants: parallel == sequential, cached == fresh (identical CostTerms,
zero recompiles), pruning never changes the fused plan, Continue mode
resumes without recompiling, and the DB/deadline satellite fixes hold.
"""
import threading
import time

import pytest

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.combinator import Combination
from repro.core.cost_model import CostTerms, combo_lower_bound
from repro.core.executor import CombinationFailed, deadline
from repro.core.segment import Segment, fragment
from repro.models.context import SegmentClause

SPACE = {"remat": ("none", "full"), "kernel": ("xla",), "block_q": (16, 32),
         "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}


def _tuner(db, project, mode="new", **kw):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    return ComParTuner(cfg, shape, mesh=None, db=db, project=project,
                       mode=mode, executor="dryrun", timeout_s=120), cfg, shape


def _sweep(tuner, **kw):
    return tuner.sweep(providers=["tensor_par", "fsdp"], clause_space=SPACE,
                       max_flags=1, **kw)


@pytest.fixture(scope="module")
def sequential():
    db = SweepDB(":memory:")
    tuner, cfg, shape = _tuner(db, "seq")
    plan, rep = _sweep(tuner, workers=1, use_cache=False, prune=False)
    return plan, rep


def test_parallel_agrees_with_sequential(sequential):
    plan_seq, rep_seq = sequential
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "par")
    plan_par, rep_par = _sweep(tuner, workers=4, use_cache=False, prune=False)
    assert plan_par.segments == plan_seq.segments
    assert rep_par.n_done == rep_seq.n_done
    assert rep_par.n_failed == rep_seq.n_failed == 0


def test_structural_sharing_compiles_unique_programs_once(sequential):
    _, rep = sequential
    # with no mesh all providers/flags collapse per segment-relevant clause:
    # far fewer compiles than rows, and every row still gets a result
    assert rep.n_scored < rep.n_combinations
    assert rep.n_scored + rep.n_shared == rep.n_done


def test_cache_hits_return_identical_costterms(sequential, tmp_path):
    plan1, rep1 = sequential
    db = SweepDB(str(tmp_path / "sweep.db"))
    t1, _, _ = _tuner(db, "c1")
    plan_a, rep_a = _sweep(t1, use_cache=True)
    assert rep_a.n_cached == 0
    t2, _, _ = _tuner(db, "c2")
    plan_b, rep_b = _sweep(t2, use_cache=True)
    # second sweep of the same config recompiles NOTHING
    assert rep_b.n_scored == 0
    assert rep_b.n_cached == rep_b.n_combinations
    assert plan_b.segments == plan_a.segments == plan1.segments
    # identical CostTerms row-for-row
    rows_a = {(r["segment"], r["cid"]): r["cost"]
              for r in db.results("c1") if r["status"] == "done"}
    rows_b = {(r["segment"], r["cid"]): r["cost"]
              for r in db.results("c2") if r["status"] == "done"}
    assert rows_a.keys() == rows_b.keys() and len(rows_a) > 0
    for k, cost in rows_a.items():
        assert CostTerms.from_dict(cost).as_dict() == \
            CostTerms.from_dict(rows_b[k]).as_dict()


def test_cache_survives_reopen(tmp_path):
    path = str(tmp_path / "sweep.db")
    t1, _, _ = _tuner(SweepDB(path), "p1")
    _sweep(t1, use_cache=True)
    t2, _, _ = _tuner(SweepDB(path), "p2")   # fresh connection
    _, rep = _sweep(t2, use_cache=True)
    assert rep.n_scored == 0
    assert rep.n_cached == rep.n_combinations


def test_pruning_never_changes_the_plan(sequential):
    plan_seq, rep_seq = sequential
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "pr")
    plan_pr, rep_pr = _sweep(tuner, workers=2, use_cache=False, prune=True,
                             prune_margin=0.0)
    assert plan_pr.segments == plan_seq.segments
    # every registered row is settled one way or another
    assert (rep_pr.n_done + rep_pr.n_failed + rep_pr.n_pruned
            == rep_pr.n_combinations)


def test_continue_mode_resumes_without_recompiling():
    db = SweepDB(":memory:")
    t1, _, _ = _tuner(db, "r", mode="new")
    plan1, rep1 = _sweep(t1, use_cache=False)
    assert rep1.n_scored > 0
    t2, _, _ = _tuner(db, "r", mode="continue")
    plan2, rep2 = _sweep(t2, use_cache=False)
    assert rep2.n_scored == 0            # all rows settled -> nothing to do
    assert rep2.n_done == rep1.n_done
    assert plan2.segments == plan1.segments


def test_lower_bound_is_below_measured_score(sequential):
    """The pruning certificate: bound <= true score for every scored row."""
    _, rep = sequential
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    segs = {s.name: s for s in fragment(cfg)}
    checked = 0
    for sname, rows in rep.per_segment.items():
        for combo, cost in rows:
            lb = combo_lower_bound(cfg, shape, segs[sname], combo)
            assert lb <= cost.total_s + 1e-12, (sname, combo.label())
            checked += 1
    assert checked > 0


def test_segment_signature_structural_identity():
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    a = Segment("g0", "stack", ("attn",), 2)
    b = Segment("g7", "stack", ("attn",), 2)      # same structure, new name
    c = Segment("g1", "stack", ("attn", "rec"), 2)
    assert a.signature(cfg, shape) == b.signature(cfg, shape)
    assert a.signature(cfg, shape) != c.signature(cfg, shape)
    # arch name is excluded; arch *fields* are not
    import dataclasses
    renamed = dataclasses.replace(cfg, name="other")
    wider = dataclasses.replace(cfg, d_model=cfg.d_model * 2)
    assert a.signature(renamed, shape) == a.signature(cfg, shape)
    assert a.signature(wider, shape) != a.signature(cfg, shape)


def test_relevant_clause_fields():
    embed = Segment("embed", "embed")
    head = Segment("head", "head")
    attn = Segment("g0", "stack", ("attn",), 2)
    moe = Segment("g0", "stack", ("attn_moe",), 2)
    rec = Segment("g0", "stack", ("rec",), 2)
    assert embed.relevant_clause_fields("train") == frozenset()
    assert head.relevant_clause_fields("train") == frozenset()
    assert {"remat", "kernel", "block_q"} <= attn.relevant_clause_fields("train")
    assert "cache_upcast" in attn.relevant_clause_fields("decode")
    assert "cache_upcast" not in attn.relevant_clause_fields("train")
    assert "moe_dispatch" in moe.relevant_clause_fields("train")
    assert "mlstm_chunk" in rec.relevant_clause_fields("train")


def test_irrelevant_clause_fields_share_scores(sequential):
    """Exactness of the projection: head-segment scores must be identical
    across combos that differ only in stack-only clause fields."""
    _, rep = sequential
    head_rows = rep.per_segment["head"]
    totals = {c.cid: t.total_s for c, t in head_rows}
    assert len(totals) > 1
    assert len(set(totals.values())) == 1


def test_cache_is_keyed_by_executor(tmp_path):
    """Analytic dry-run scores must never be served to a wall-clock sweep
    sharing the same DB file (and vice versa)."""
    db = SweepDB(str(tmp_path / "sweep.db"))
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
    t1 = ComParTuner(cfg, shape, mesh=None, db=db, project="dry",
                     mode="new", executor="dryrun", timeout_s=120)
    t1.sweep(providers=["fsdp"], clause_space=space, max_flags=0)
    t2 = ComParTuner(cfg, shape, mesh=None, db=db, project="wall",
                     mode="new", executor="wallclock", timeout_s=120)
    _, rep = t2.sweep(providers=["fsdp"], clause_space=space, max_flags=0)
    assert rep.n_cached == 0 and rep.n_scored > 0


def test_prune_disabled_under_boundary_cost_fusion():
    """The lower-bound certificate covers per-segment argmin only; under
    Viterbi fusion pruning must be switched off."""
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "bc")
    plan, rep = _sweep(tuner, prune=True, boundary_costs=True,
                       use_cache=False)
    assert rep.n_pruned == 0
    assert plan.meta["fusion"] == "viterbi-boundary"


def test_wallclock_clamps_workers(monkeypatch):
    """Concurrent timed runs contend on the device: a wallclock sweep must
    run its measurements sequentially even if workers>1 is requested."""
    from repro.core import executor as E
    seen = {}
    orig = E.ParallelSweepRunner.__init__

    def spy(self, ex, cfg, shape, *, workers=1, **kw):
        seen["workers"] = workers
        orig(self, ex, cfg, shape, workers=workers, **kw)

    monkeypatch.setattr(E.ParallelSweepRunner, "__init__", spy)
    import repro.core.tuner as T
    monkeypatch.setattr(T, "ParallelSweepRunner", E.ParallelSweepRunner)
    db = SweepDB(":memory:")
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
    t = ComParTuner(cfg, shape, mesh=None, db=db, project="wc",
                    mode="new", executor="wallclock", timeout_s=120)
    t.sweep(providers=["fsdp"], clause_space=space, max_flags=0,
            workers=8, use_cache=False)
    assert seen["workers"] == 1


def test_deadline_failures_are_not_cached(tmp_path):
    db = SweepDB(str(tmp_path / "sweep.db"))
    t1, _, _ = _tuner(db, "dl")
    t1.executor.timeout_s = 0.001   # soft-fail everything scored
    with pytest.raises(ValueError):  # nothing valid left -> fuse() refuses
        _sweep(t1, use_cache=True, workers=2)
    rows = db.results("dl")
    assert rows and all(r["status"] == "failed" for r in rows)
    assert db.cache_size() == 0
    # a retry with a sane budget recompiles (nothing poisoned)...
    t2, _, _ = _tuner(db, "dl2")
    _, rep2 = _sweep(t2, use_cache=True)
    assert rep2.n_done == rep2.n_combinations
    # ...and its good scores DO land in the cache
    assert db.cache_size() == rep2.n_scored


def test_wallclock_disables_prune():
    """combo_lower_bound divides by an analytic hw peak; against measured
    wall seconds the certificate doesn't hold, so prune must switch off."""
    db = SweepDB(":memory:")
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
    t = ComParTuner(cfg, shape, mesh=None, db=db, project="wp",
                    mode="new", executor="wallclock", timeout_s=120)
    _, rep = t.sweep(providers=["fsdp"], clause_space=space, max_flags=0,
                     prune=True, use_cache=False)
    assert rep.n_pruned == 0 and rep.n_done == rep.n_combinations


def test_unexpected_worker_exception_fails_row_not_sweep(monkeypatch):
    """A non-CombinationFailed bug in scoring must become a failed row;
    an escaping exception would abort the sweep mid-batch."""
    db = SweepDB(":memory:")
    tuner, _, _ = _tuner(db, "boom")
    orig = tuner.executor.score_segment
    calls = {"n": 0}

    def flaky(cfg, shape, seg, combo):
        calls["n"] += 1
        if calls["n"] == 3:   # a stack group — its siblings still succeed
            raise ValueError("synthetic analysis bug")
        return orig(cfg, shape, seg, combo)

    monkeypatch.setattr(tuner.executor, "score_segment", flaky)
    plan, rep = _sweep(tuner, use_cache=False)
    assert rep.n_failed > 0
    assert rep.n_done + rep.n_failed == rep.n_combinations
    rows = [r for r in db.results("boom") if r["status"] == "failed"]
    assert any("ValueError" in r["error"] for r in rows)


# --- satellite fixes ---------------------------------------------------------

def test_db_record_unregistered_raises():
    db = SweepDB(":memory:")
    db.open_project("p", "new")
    with pytest.raises(KeyError):
        db.record("p", "g0", "deadbeef0000", status="done",
                  cost={"total_s": 1.0})


def test_db_record_many_partial_unregistered_raises_and_rolls_back():
    db = SweepDB(":memory:")
    db.open_project("p", "new")
    combo = Combination("fsdp", frozenset(), SegmentClause())
    db.register("p", "g0", combo)
    with pytest.raises(KeyError):
        db.record_many("p", [
            {"segment": "g0", "cid": combo.cid, "status": "done",
             "cost": {"total_s": 1.0}},
            {"segment": "g0", "cid": "missing000000", "status": "done"},
        ])
    assert db.status("p", "g0", combo.cid) == "pending"


def test_deadline_off_main_thread_soft_fails():
    out = {}

    def burn(cpu_s):
        t0 = time.thread_time()
        while time.thread_time() - t0 < cpu_s:
            sum(i * i for i in range(1000))

    def body():
        try:
            with deadline(1):
                burn(1.1)    # the soft deadline is CPU time, not wall
            out["raised"] = False
        except CombinationFailed as e:
            out["raised"] = True
            out["msg"] = str(e)

    t = threading.Thread(target=body)
    t.start()
    t.join()
    assert out["raised"] and "soft" in out["msg"]


def test_deadline_off_main_thread_passes_within_budget():
    out = {}

    def body():
        with deadline(30):
            out["ok"] = True

    t = threading.Thread(target=body)
    t.start()
    t.join()
    assert out.get("ok")
