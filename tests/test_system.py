"""End-to-end behaviour tests for the whole system: launcher-level train
with checkpoint/restart, serving loop, and the full ComPar pipeline on a
real (smoke) model with wall-clock measurement."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_shape


def test_train_launcher_end_to_end(tmp_path):
    from repro.launch.train import train
    args = ["--arch", "stablelm-3b", "--smoke", "--steps", "60",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "30",
            "--log-every", "20", "--seed", "3", "--warmup", "5"]
    losses = train(args)
    assert len(losses) == 60
    import numpy as np
    assert np.mean(losses[-10:]) < np.mean(losses[:10])   # learns
    # restart resumes from checkpoint step 60 and is a no-op
    assert train(args) == []


def test_serve_launcher_end_to_end():
    from repro.launch.serve import serve
    done = serve(["--arch", "xlstm-125m", "--smoke", "--batch", "2",
                  "--tokens", "8", "--cache-len", "16", "--requests", "3",
                  "--prompt-len", "2"])
    vocab = get_arch("xlstm-125m").smoke().vocab_size
    assert len(done) == 3
    for c in done.values():
        assert len(c.tokens) == 8 and c.finish_reason == "length"
        assert max(c.tokens) < vocab


def test_dryrun_input_specs_cover_all_cells():
    from repro.launch.dryrun import input_specs
    from repro.configs import ARCHS, SHAPES
    n = 0
    for a in ARCHS:
        for s in SHAPES:
            spec = input_specs(a, s)
            assert spec, (a, s)
            leaves = jax.tree.leaves(
                spec, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
            n += 1
    assert n == 40


def test_full_compar_pipeline_wallclock():
    """The paper's loop with real empirical timing (tiny model, CPU):
    sweep -> fuse -> the fused plan actually executes."""
    from repro.core import ComParTuner
    from repro.core.plan import build_contexts
    from repro.models import forward, init_params, model_specs

    cfg = get_arch("stablelm-3b").smoke()
    shape = get_shape("train_4k").smoke()
    tuner = ComParTuner(cfg, shape, mesh=None, executor="wallclock",
                        project="e2e", timeout_s=120)
    space = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
             "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}
    plan, rep = tuner.sweep(providers=["hybrid2d"], clause_space=space,
                            max_flags=1)
    assert rep.n_done > 0
    params = init_params(model_specs(cfg), jax.random.key(0))
    ctxs = build_contexts(cfg, None, plan)
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits, _ = forward(params, {"tokens": tokens}, cfg, ctxs)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
