"""Training infrastructure: loss decreases, microbatch-equivalence,
checkpoint/restart exact replay, data determinism, optimizer-state
compression, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # container lacks hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_arch, get_shape
from repro.core.combinator import GlobalKnobs
from repro.core.plan import uniform_plan
from repro.data.pipeline import SyntheticLM
from repro.models.context import SegmentClause
from repro.optim.adamw import adamw_init, adamw_update, cosine_lr
from repro.train.step import init_train_state, jit_train_step


def tiny_setup(arch="granite-8b", mb=1, **clause_kw):
    cfg = get_arch(arch).smoke()
    # donate=False: tests re-run steps from the same initial state
    plan = uniform_plan(cfg, "fsdp",
                        clause=SegmentClause(**clause_kw),
                        knobs=GlobalKnobs(microbatches=mb, donate=False))
    step, _ = jit_train_step(cfg, None, plan)
    params, opt = init_train_state(cfg, plan, jax.random.key(0))
    return cfg, step, params, opt


def make_batch(cfg, B=4, S=16, seed=1):
    ks = jax.random.split(jax.random.key(seed), 2)
    return {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(ks[1], (B, S), 0,
                                          cfg.vocab_size)}


def test_loss_decreases_on_repeated_batch():
    cfg, step, params, opt = tiny_setup()
    batch = make_batch(cfg)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["total_loss"]))
    assert losses[-1] < losses[0] - 0.01, losses


def test_microbatch_grad_equivalence():
    """mb=2 gradient accumulation must match mb=1 on the same batch
    (same loss trajectory within fp tolerance)."""
    cfg1, step1, p1, o1 = tiny_setup(mb=1)
    cfg2, step2, p2, o2 = tiny_setup(mb=2)
    batch = make_batch(cfg1)
    for _ in range(3):
        p1, o1, m1 = step1(p1, o1, batch)
        p2, o2, m2 = step2(p2, o2, batch)
    np.testing.assert_allclose(float(m1["total_loss"]),
                               float(m2["total_loss"]), rtol=1e-3)


def test_remat_does_not_change_loss():
    cfg1, step1, p1, o1 = tiny_setup(remat="none")
    cfg2, step2, p2, o2 = tiny_setup(remat="full")
    batch = make_batch(cfg1)
    p1, o1, m1 = step1(p1, o1, batch)
    p2, o2, m2 = step2(p2, o2, batch)
    np.testing.assert_allclose(float(m1["total_loss"]),
                               float(m2["total_loss"]), rtol=1e-5)


def test_checkpoint_restart_exact_replay(tmp_path):
    """Train 6 steps straight vs train 3 + crash + restore + 3 — identical
    final loss (the fault-tolerance contract)."""
    cfg, step, params, opt = tiny_setup()
    shape = get_shape("train_4k").smoke()
    data = SyntheticLM(cfg, shape, seed=7)

    def run(params, opt, data, lo, hi):
        m = None
        for s in range(lo, hi):
            params, opt, m = step(params, opt, data.batch_at(s))
        return params, opt, float(m["total_loss"])

    pA, oA, lossA = run(params, opt, data, 0, 6)

    store = CheckpointStore(str(tmp_path), keep=2)
    pB, oB, _ = run(params, opt, data, 0, 3)
    store.save(3, {"params": pB, "opt": oB},
               extra={"data": {"seed": 7, "step": 3}})
    # simulated crash: fresh objects, restore
    stepr, _ = jit_train_step(cfg, None, uniform_plan(
        cfg, "fsdp", clause=SegmentClause()))
    s0, state, extra = store.restore({"params": pB, "opt": oB})
    assert s0 == 3 and extra["data"]["step"] == 3
    pC, oC, lossC = run(state["params"], state["opt"],
                        SyntheticLM(cfg, shape, seed=7), 3, 6)
    np.testing.assert_allclose(lossA, lossC, rtol=1e-6)


def test_checkpoint_atomicity(tmp_path):
    """A step dir without a manifest must be invisible to restore."""
    store = CheckpointStore(str(tmp_path), keep=5)
    tree = {"w": jnp.ones((4,))}
    store.save(1, {"params": tree})
    # simulate crash mid-write of step 2: dir exists, no manifest
    os.makedirs(os.path.join(str(tmp_path), "step_00000002"))
    assert store.latest_step() == 1
    step, out, _ = store.restore({"params": tree})
    assert step == 1


@given(st.integers(0, 2 ** 20), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_data_pure_function_of_step(seed, step):
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    d1 = SyntheticLM(cfg, shape, seed=seed)
    d2 = SyntheticLM(cfg, shape, seed=seed)
    b1, b2 = d1.batch_at(step), d2.batch_at(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert int(b1["tokens"].max()) < cfg.vocab_size


def test_data_host_slices_differ():
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    hs = [SyntheticLM(cfg, shape, seed=1, host_index=i, host_count=4)
          for i in range(4)]
    toks = [np.asarray(h.batch_at(0)["tokens"]) for h in hs]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(toks[i], toks[j])


def test_optimizer_state_compression_halves_bytes():
    params = {"w": jnp.zeros((128, 128), jnp.bfloat16)}
    full = adamw_init(params, "float32")
    comp = adamw_init(params, "bfloat16")
    assert comp.m["w"].dtype == jnp.bfloat16
    assert full.m["w"].nbytes == 2 * comp.m["w"].nbytes


def test_adamw_converges_quadratic():
    w = jnp.array([4.0, -3.0])
    params = {"w": w}
    state = adamw_init(params)
    for i in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(
            grads, state, params, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_lr_schedule():
    assert float(cosine_lr(jnp.int32(0), peak_lr=1.0, warmup=10)) == 0.0
    assert abs(float(cosine_lr(jnp.int32(10), peak_lr=1.0, warmup=10,
                               total=100)) - 1.0) < 1e-6
    end = float(cosine_lr(jnp.int32(100), peak_lr=1.0, warmup=10,
                          total=100))
    assert end < 0.2


# --- HLO analyzer ------------------------------------------------------------

def test_hlo_flops_counts_scan_trips():
    from repro.runtime.hlo import analyze_hlo

    def body(c, w):
        return jnp.tanh(c @ w), None

    def prog(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    compiled = jax.jit(prog).lower(x, ws).compile()
    res = analyze_hlo(compiled.as_text())
    expect = 7 * 2 * 64 * 128 * 128
    assert abs(res["flops"] - expect) / expect < 0.01
    # XLA's own cost_analysis misses the trips — that's why we parse
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # jax < 0.5: one dict per device
        ca = ca[0]
    assert ca["flops"] < res["flops"]


def test_hlo_collective_parsing_synthetic():
    from repro.runtime.hlo import collective_bytes
    txt = """
HloModule m
ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %ar = f32[16,128]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    res = collective_bytes(txt)
    ag = 256 * 128 * 4 * 15 / 16
    ar = 2 * 16 * 128 * 4 * 3 / 4
    assert abs(res["all-gather"] - ag) < 1
    assert abs(res["all-reduce"] - ar) < 1
    assert abs(res["total"] - (ag + ar)) < 2
