"""End-to-end ComParX tuner: sweep -> DB -> fuse, Continue-mode resume,
validator black-box checks."""
import jax
import pytest

from repro.configs import get_arch, get_shape
from repro.core import ComParTuner, SweepDB
from repro.core.combinator import Combination
from repro.core.fusion import best_uniform
from repro.core.validator import validate_combination, validate_plan
from repro.core.plan import uniform_plan
from repro.models.context import SegmentClause

SPACE = {"remat": ("none",), "kernel": ("xla",), "block_q": (16,),
         "block_k": (16,), "scan_unroll": (1,), "mlstm_chunk": (16,)}


@pytest.fixture(scope="module")
def swept():
    cfg = get_arch("granite-8b").smoke()
    shape = get_shape("train_4k").smoke()
    db = SweepDB(":memory:")
    tuner = ComParTuner(cfg, shape, mesh=None, db=db, project="t",
                        mode="new", executor="dryrun", timeout_s=120)
    plan, rep = tuner.sweep(providers=["tensor_par", "fsdp"],
                            clause_space=SPACE, max_flags=1)
    return cfg, shape, db, tuner, plan, rep


def test_sweep_completes_and_fuses(swept):
    cfg, shape, db, tuner, plan, rep = swept
    assert rep.n_done > 0
    assert rep.n_failed == 0
    assert set(plan.segments) == {"embed", "g0", "head"}
    assert rep.paper_count > rep.n_combinations  # formula is an upper bound


def test_fused_plan_beats_or_equals_uniform_baselines(swept):
    cfg, shape, db, tuner, plan, rep = swept
    baselines = tuner.baselines()
    assert baselines, "no uniform baseline found"
    assert plan.meta["predicted_total_s"] <= min(baselines.values()) + 1e-12


def test_continue_mode_skips_done(swept):
    cfg, shape, db, tuner, plan, rep = swept
    t2 = ComParTuner(cfg, shape, mesh=None, db=db, project="t",
                     mode="continue", executor="dryrun")
    import time
    t0 = time.time()
    plan2, rep2 = t2.sweep(providers=["tensor_par", "fsdp"],
                           clause_space=SPACE, max_flags=1)
    # everything cached -> near-instant and identical fusion
    assert time.time() - t0 < 10.0
    assert rep2.n_done == rep.n_done
    assert plan2.segments == plan.segments


def test_validator_accepts_real_combinations():
    cfg = get_arch("recurrentgemma-2b").smoke()
    ok, msg = validate_combination(
        cfg, Combination("tensor_par", frozenset(),
                         SegmentClause(remat="full", kernel="xla")))
    assert ok, msg


def test_validator_accepts_pallas_clause():
    cfg = get_arch("recurrentgemma-2b").smoke()
    ok, msg = validate_combination(
        cfg, Combination("fsdp", frozenset(),
                         SegmentClause(kernel="pallas", mlstm_chunk=16,
                                       block_q=16, block_k=16)))
    assert ok, msg


def test_validator_rejects_broken_plan(monkeypatch):
    """A combination whose execution diverges must be rejected (the
    paper's black-box test).  We corrupt the forward pass only for
    candidates whose clause says remat='full', then validate such a
    candidate against the clean remat='none' reference."""
    import repro.core.validator as V
    cfg = get_arch("granite-8b").smoke()
    real_forward = V.forward

    def selectively_broken(params, batch, cfg_, ctxs):
        logits, aux = real_forward(params, batch, cfg_, ctxs)
        clauses = ([c.clause for c in ctxs.values()]
                   if isinstance(ctxs, dict) else [ctxs.clause])
        if any(c.remat == "full" for c in clauses):
            logits = logits + 7.0          # corrupted numerics
        return logits, aux

    monkeypatch.setattr(V, "forward", selectively_broken)
    plan_bad = uniform_plan(cfg, "fsdp",
                            clause=SegmentClause(remat="full"))
    ok, msg = V.validate_plan(cfg, plan_bad, reference=None)
    assert not ok and "mismatch" in msg
